//! Service-cost calibration: fit the virtual clock's cost model from
//! measured [`crate::canny::StageTimes`].
//!
//! The virtual driver charges each dispatch
//! `overhead_ns + cost_ns_per_pixel * pixels`. PR 1 shipped synthetic
//! constants for those two numbers; this module replaces them with a
//! model fitted to the *real* detector on the *current* host: probe a
//! grid of shapes (each measured as the fieldwise-min of repeated runs,
//! via [`crate::canny::CannyPipeline::probe_shape`]), then least-squares
//! fit measured nanoseconds against pixel count. With a calibration
//! installed, virtual-time p50/p95/p99 predictions track the wall-clock
//! driver instead of a guess — the integration suite asserts the two
//! agree within a documented tolerance band.
//!
//! Calibrations serialize to JSON (schema in [`crate::service`] docs) so
//! a probe done once can be replayed deterministically with
//! `cannyd serve --calibration file.json`.

use std::path::Path;

use crate::coordinator::Detector;
use crate::error::{Error, Result};
use crate::service::request::Shape;
use crate::util::json::Json;

/// Fallback probe grid when no trace shapes are available (spans the
/// synthetic size palette and a couple of larger shapes so the fit has
/// leverage on the per-pixel slope).
pub const DEFAULT_PROBE_SHAPES: &[(usize, usize)] =
    &[(64, 64), (96, 96), (128, 128), (192, 192), (256, 256)];

/// Detection runs per probe shape; the fieldwise minimum is kept
/// (min-of-repeats strips preemption noise on a timeshared host).
pub const PROBE_REPEATS: usize = 3;

/// One measured shape: the min-of-repeats end-to-end detection cost.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ProbePoint {
    pub width: usize,
    pub height: usize,
    /// Measured detection nanoseconds for this shape.
    pub ns: u64,
}

impl ProbePoint {
    pub fn pixels(&self) -> usize {
        self.width * self.height
    }
}

/// A fitted per-engine service-cost model: `t(px) = overhead_ns +
/// cost_ns_per_pixel * px`, plus the probe points it was fitted from
/// (kept for provenance and for re-fitting offline).
#[derive(Clone, Debug)]
pub struct Calibration {
    /// Engine the probes ran on (provenance only).
    pub engine: String,
    /// Worker threads per lane during probing (provenance only).
    pub workers: usize,
    /// Fitted per-dispatch fixed cost, ns (intercept, clamped >= 0).
    pub overhead_ns: u64,
    /// Fitted per-pixel cost, ns (slope, clamped >= 0).
    pub cost_ns_per_pixel: f64,
    pub probes: Vec<ProbePoint>,
}

impl Calibration {
    /// Modeled service cost for one dispatch of `pixels` total pixels.
    pub fn service_ns(&self, pixels: usize) -> u64 {
        self.overhead_ns
            .saturating_add((self.cost_ns_per_pixel * pixels as f64).round() as u64)
    }

    /// Least-squares fit `ns = a + b * pixels` over the probe points,
    /// clamped to the physical range (`a >= 0`, `b >= 0`): a negative
    /// intercept refits through the origin, a negative slope degrades to
    /// a flat per-dispatch cost. A single distinct pixel count fits
    /// through the origin (no leverage to split overhead from slope).
    pub fn fit(probes: Vec<ProbePoint>, engine: &str, workers: usize) -> Result<Calibration> {
        if probes.is_empty() {
            return Err(Error::Config("calibration: no probe points".into()));
        }
        let n = probes.len() as f64;
        let (mut sx, mut sy, mut sxx, mut sxy) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
        for p in &probes {
            let (x, y) = (p.pixels() as f64, p.ns as f64);
            sx += x;
            sy += y;
            sxx += x * x;
            sxy += x * y;
        }
        let var = sxx - sx * sx / n;
        let (mut a, mut b) = if var <= f64::EPSILON * sxx {
            (0.0, sy / sx)
        } else {
            let b = (sxy - sx * sy / n) / var;
            (sy / n - b * sx / n, b)
        };
        if b < 0.0 {
            (a, b) = (sy / n, 0.0);
        } else if a < 0.0 {
            (a, b) = (0.0, sxy / sxx);
        }
        Ok(Calibration {
            engine: engine.to_string(),
            workers,
            overhead_ns: a.round() as u64,
            cost_ns_per_pixel: b,
            probes,
        })
    }

    /// Measure `shapes` on `det` (each the fieldwise-min of `repeats`
    /// runs) and fit the cost model.
    pub fn probe(det: &Detector, shapes: &[Shape], repeats: usize) -> Result<Calibration> {
        let mut probes = Vec::with_capacity(shapes.len());
        for s in shapes {
            let times = det.pipeline().probe_shape(s.width, s.height, repeats, det.params())?;
            probes.push(ProbePoint { width: s.width, height: s.height, ns: times.total_ns });
        }
        Calibration::fit(probes, det.engine().name(), det.n_workers())
    }

    /// Serialize (schema documented in the [`crate::service`] module).
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("format".into(), Json::Num(1.0));
        m.insert("engine".into(), Json::Str(self.engine.clone()));
        m.insert("workers".into(), Json::Num(self.workers as f64));
        m.insert("overhead_ns".into(), Json::Num(self.overhead_ns as f64));
        m.insert("cost_ns_per_pixel".into(), Json::Num(self.cost_ns_per_pixel));
        let probes = self
            .probes
            .iter()
            .map(|p| {
                let mut pm = std::collections::BTreeMap::new();
                pm.insert("width".into(), Json::Num(p.width as f64));
                pm.insert("height".into(), Json::Num(p.height as f64));
                pm.insert("ns".into(), Json::Num(p.ns as f64));
                Json::Obj(pm)
            })
            .collect();
        m.insert("probes".into(), Json::Arr(probes));
        Json::Obj(m)
    }

    pub fn to_json_string(&self) -> String {
        self.to_json().dump()
    }

    /// Parse + validate a calibration document. `overhead_ns` and
    /// `cost_ns_per_pixel` are required and must be finite and >= 0;
    /// `engine`, `workers` and `probes` are optional provenance. A
    /// `format` other than 1 (or absent) is rejected so future schema
    /// revisions fail loudly instead of loading under v1 semantics.
    pub fn from_json(text: &str) -> Result<Calibration> {
        let j = Json::parse(text)?;
        if let Some(f) = j.get("format").and_then(Json::as_f64) {
            if f != 1.0 {
                return Err(Error::Config(format!(
                    "calibration: unsupported format {f} (this build reads format 1)"
                )));
            }
        }
        let num = |key: &str| -> Result<f64> {
            let v = j
                .get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| Error::Config(format!("calibration: missing/invalid `{key}`")))?;
            if !(v.is_finite() && v >= 0.0) {
                return Err(Error::Config(format!(
                    "calibration: `{key}` must be finite and >= 0, got {v}"
                )));
            }
            Ok(v)
        };
        let overhead_ns = num("overhead_ns")? as u64;
        let cost_ns_per_pixel = num("cost_ns_per_pixel")?;
        let mut probes = Vec::new();
        if let Some(arr) = j.get("probes").and_then(Json::as_arr) {
            for (k, p) in arr.iter().enumerate() {
                let field = |name: &str| -> Result<f64> {
                    p.get(name).and_then(Json::as_f64).ok_or_else(|| {
                        Error::Config(format!("calibration probe {k}: missing/invalid `{name}`"))
                    })
                };
                probes.push(ProbePoint {
                    width: field("width")? as usize,
                    height: field("height")? as usize,
                    ns: field("ns")? as u64,
                });
            }
        }
        Ok(Calibration {
            engine: j.get("engine").and_then(Json::as_str).unwrap_or("").to_string(),
            workers: j.get("workers").and_then(Json::as_usize).unwrap_or(0),
            overhead_ns,
            cost_ns_per_pixel,
            probes,
        })
    }

    /// [`Calibration::from_json`] over a file.
    pub fn from_json_file(path: &Path) -> Result<Calibration> {
        Calibration::from_json(&std::fs::read_to_string(path)?)
    }

    /// Write the JSON document to `path`.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json_string())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(w: usize, h: usize, ns: u64) -> ProbePoint {
        ProbePoint { width: w, height: h, ns }
    }

    #[test]
    fn fit_recovers_a_linear_model() {
        // ns = 50_000 + 3 * px, exactly.
        let probes: Vec<ProbePoint> = [(64, 64), (128, 128), (256, 256)]
            .iter()
            .map(|&(w, h)| point(w, h, 50_000 + 3 * (w * h) as u64))
            .collect();
        let c = Calibration::fit(probes, "patterns", 4).unwrap();
        assert!((c.overhead_ns as i64 - 50_000).abs() <= 1, "overhead {}", c.overhead_ns);
        assert!((c.cost_ns_per_pixel - 3.0).abs() < 1e-6, "slope {}", c.cost_ns_per_pixel);
        assert_eq!(c.service_ns(10_000), c.overhead_ns + 30_000);
    }

    #[test]
    fn fit_single_shape_goes_through_the_origin() {
        let c = Calibration::fit(vec![point(100, 100, 40_000)], "serial", 1).unwrap();
        assert_eq!(c.overhead_ns, 0);
        assert!((c.cost_ns_per_pixel - 4.0).abs() < 1e-9);
    }

    #[test]
    fn fit_clamps_unphysical_slopes_and_intercepts() {
        // Decreasing cost with size -> slope clamps to 0, flat mean cost.
        let c = Calibration::fit(
            vec![point(64, 64, 90_000), point(256, 256, 10_000)],
            "patterns",
            2,
        )
        .unwrap();
        assert_eq!(c.cost_ns_per_pixel, 0.0);
        assert_eq!(c.overhead_ns, 50_000);
        // Negative intercept (tiny fixed cost) -> refit through origin.
        let c2 = Calibration::fit(
            vec![point(64, 64, 1_000), point(256, 256, 300_000)],
            "patterns",
            2,
        )
        .unwrap();
        assert_eq!(c2.overhead_ns, 0);
        assert!(c2.cost_ns_per_pixel > 0.0);
        assert!(Calibration::fit(Vec::new(), "patterns", 1).is_err());
    }

    #[test]
    fn json_roundtrip_preserves_the_model() {
        let c = Calibration {
            engine: "tiled".into(),
            workers: 3,
            overhead_ns: 120_000,
            cost_ns_per_pixel: 3.5,
            probes: vec![point(96, 96, 152_256)],
        };
        let back = Calibration::from_json(&c.to_json_string()).unwrap();
        assert_eq!(back.engine, "tiled");
        assert_eq!(back.workers, 3);
        assert_eq!(back.overhead_ns, 120_000);
        assert!((back.cost_ns_per_pixel - 3.5).abs() < 1e-12);
        assert_eq!(back.probes, c.probes);
    }

    #[test]
    fn from_json_rejects_bad_documents() {
        assert!(Calibration::from_json("{}").is_err());
        assert!(Calibration::from_json(r#"{"overhead_ns": 1}"#).is_err());
        assert!(
            Calibration::from_json(r#"{"overhead_ns": -5, "cost_ns_per_pixel": 1}"#).is_err()
        );
        assert!(
            Calibration::from_json(r#"{"overhead_ns": 1, "cost_ns_per_pixel": 1e999}"#).is_err()
        );
        // A future schema revision is rejected, not misread as v1.
        assert!(Calibration::from_json(
            r#"{"format": 2, "overhead_ns": 1, "cost_ns_per_pixel": 1}"#
        )
        .is_err());
        // Minimal hand-written model is accepted.
        let c = Calibration::from_json(r#"{"overhead_ns": 1000, "cost_ns_per_pixel": 2}"#)
            .unwrap();
        assert_eq!(c.service_ns(10), 1020);
    }

    #[test]
    fn probe_measures_a_real_detector() {
        let det = Detector::builder().workers(1).build().unwrap();
        let c = Calibration::probe(&det, &[Shape { width: 48, height: 32 }], 1).unwrap();
        assert_eq!(c.probes.len(), 1);
        assert!(c.probes[0].ns > 0, "probe must measure real work");
        assert!(c.service_ns(48 * 32) > 0);
    }
}
