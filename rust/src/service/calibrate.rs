//! Service-cost calibration: fit the virtual clock's cost model from
//! measured per-stage [`crate::canny::StageRecord`]s.
//!
//! The virtual driver charges each dispatch
//! `overhead_ns + cost_ns_per_pixel * pixels`. PR 1 shipped synthetic
//! constants for those two numbers; this module replaces them with a
//! model fitted to the *real* detector on the *current* host: probe a
//! grid of shapes (each stage measured as the min of repeated runs),
//! then least-squares fit measured nanoseconds against pixel count —
//! **end-to-end** (the full-detection cost the virtual lanes charge)
//! and **per stage** ([`StageCost`], one linear model per stage span),
//! so partial-pipeline request kinds (front-only, re-threshold) are
//! charged only the stages they actually run, and batch coalescing can
//! model fused-front amortization. With a calibration installed,
//! virtual-time p50/p95/p99 predictions track the wall-clock driver
//! instead of a guess — the integration suite asserts the two agree
//! within a documented tolerance band.
//!
//! Calibrations serialize to JSON (schema in [`crate::service`] docs) so
//! a probe done once can be replayed deterministically with
//! `cannyd serve --calibration file.json`.

use std::collections::BTreeMap;
use std::path::Path;

use crate::coordinator::Detector;
use crate::error::{Error, Result};
use crate::service::request::Shape;
use crate::util::json::Json;

/// Fallback probe grid when no trace shapes are available (spans the
/// synthetic size palette and a couple of larger shapes so the fit has
/// leverage on the per-pixel slope).
pub const DEFAULT_PROBE_SHAPES: &[(usize, usize)] =
    &[(64, 64), (96, 96), (128, 128), (192, 192), (256, 256)];

/// Detection runs per probe shape; the fieldwise minimum is kept
/// (min-of-repeats strips preemption noise on a timeshared host).
pub const PROBE_REPEATS: usize = 3;

/// One measured shape: the min-of-repeats end-to-end detection cost.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ProbePoint {
    pub width: usize,
    pub height: usize,
    /// Measured detection nanoseconds for this shape.
    pub ns: u64,
}

impl ProbePoint {
    pub fn pixels(&self) -> usize {
        self.width * self.height
    }
}

/// A per-stage linear cost model: `t(px) = overhead_ns +
/// cost_ns_per_pixel * px` for one stage span (`"pad"`, `"gaussian"`,
/// …, `"front"` for a fused tile front, `"hysteresis"`), with `px` the
/// *image* pixel count.
#[derive(Clone, Debug, PartialEq)]
pub struct StageCost {
    /// Stage span name ([`crate::canny::StageRecord::span_name`]).
    pub stage: String,
    pub overhead_ns: u64,
    pub cost_ns_per_pixel: f64,
}

impl StageCost {
    pub fn service_ns(&self, pixels: usize) -> u64 {
        self.overhead_ns
            .saturating_add((self.cost_ns_per_pixel * pixels as f64).round() as u64)
    }
}

/// Least-squares fit `y = a + b x` over `(x, y)` points, clamped to the
/// physical range (`a >= 0`, `b >= 0`): a negative intercept refits
/// through the origin, a negative slope degrades to a flat cost. A
/// single distinct x fits through the origin (no leverage to split
/// overhead from slope).
fn fit_line(points: &[(f64, f64)]) -> (f64, f64) {
    let n = points.len() as f64;
    let (mut sx, mut sy, mut sxx, mut sxy) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for &(x, y) in points {
        sx += x;
        sy += y;
        sxx += x * x;
        sxy += x * y;
    }
    let var = sxx - sx * sx / n;
    let (mut a, mut b) = if var <= f64::EPSILON * sxx {
        (0.0, sy / sx)
    } else {
        let b = (sxy - sx * sy / n) / var;
        (sy / n - b * sx / n, b)
    };
    if b < 0.0 {
        (a, b) = (sy / n, 0.0);
    } else if a < 0.0 {
        (a, b) = (0.0, sxy / sxx);
    }
    (a, b)
}

/// A fitted per-engine service-cost model: the end-to-end line
/// `t(px) = overhead_ns + cost_ns_per_pixel * px`, per-stage lines
/// ([`StageCost`]) for partial-pipeline request kinds, plus the probe
/// points it was fitted from (kept for provenance and for re-fitting
/// offline).
#[derive(Clone, Debug)]
pub struct Calibration {
    /// Engine the probes ran on (provenance only).
    pub engine: String,
    /// Worker threads per lane during probing (provenance only).
    pub workers: usize,
    /// Fitted per-dispatch fixed cost, ns (intercept, clamped >= 0).
    pub overhead_ns: u64,
    /// Fitted per-pixel cost, ns (slope, clamped >= 0).
    pub cost_ns_per_pixel: f64,
    /// Per-stage fits, one per stage span measured on every probe
    /// shape (empty on pre-stage-graph calibration files).
    pub stages: Vec<StageCost>,
    pub probes: Vec<ProbePoint>,
}

impl Calibration {
    /// Modeled service cost for one dispatch of `pixels` total pixels
    /// (the full pipeline, end-to-end fit).
    pub fn service_ns(&self, pixels: usize) -> u64 {
        self.overhead_ns
            .saturating_add((self.cost_ns_per_pixel * pixels as f64).round() as u64)
    }

    /// Modeled cost of running exactly `stage_names` on `pixels`
    /// pixels: the sum of those stages' fitted lines. `None` when any
    /// stage has no fit (e.g. a fused-front probe never measured
    /// `"gaussian"` on its own) — the caller falls back to a synthetic
    /// fraction of the end-to-end cost.
    pub fn stage_service_ns(&self, stage_names: &[&str], pixels: usize) -> Option<u64> {
        let mut total = 0u64;
        for name in stage_names {
            let c = self.stages.iter().find(|s| s.stage == *name)?;
            total = total.saturating_add(c.service_ns(pixels));
        }
        Some(total)
    }

    /// Fit the end-to-end model over the probe points (clamped as
    /// described on the module's line-fit helper: negative intercepts
    /// refit through the origin, negative slopes degrade to a flat
    /// cost). Per-stage fits are added by [`Calibration::probe`],
    /// which has the records.
    pub fn fit(probes: Vec<ProbePoint>, engine: &str, workers: usize) -> Result<Calibration> {
        if probes.is_empty() {
            return Err(Error::Config("calibration: no probe points".into()));
        }
        let pts: Vec<(f64, f64)> =
            probes.iter().map(|p| (p.pixels() as f64, p.ns as f64)).collect();
        let (a, b) = fit_line(&pts);
        Ok(Calibration {
            engine: engine.to_string(),
            workers,
            overhead_ns: a.round() as u64,
            cost_ns_per_pixel: b,
            stages: Vec::new(),
            probes,
        })
    }

    /// Measure `shapes` on `det` (each stage and the total taken as the
    /// min over `repeats` runs) and fit the cost models — end-to-end
    /// from the totals, per-stage from the [`crate::canny::StageRecord`]
    /// walls. Stage fits cover only spans measured on *every* shape, so
    /// a model is never extrapolated from one lucky sample.
    pub fn probe(det: &Detector, shapes: &[Shape], repeats: usize) -> Result<Calibration> {
        let mut probes = Vec::with_capacity(shapes.len());
        // span name -> (pixels, min ns) per shape, in shape order.
        let mut stage_points: BTreeMap<String, Vec<(f64, f64)>> = BTreeMap::new();
        for s in shapes {
            let img = crate::canny::CannyPipeline::probe_image(s.width, s.height);
            let mut best_total = u64::MAX;
            let mut best_stage: BTreeMap<String, u64> = BTreeMap::new();
            for _ in 0..repeats.max(1) {
                let out = det.detect_full(&img, det.params())?;
                best_total = best_total.min(out.times.total_ns);
                for r in &out.records {
                    let e = best_stage.entry(r.span_name().to_string()).or_insert(u64::MAX);
                    *e = (*e).min(r.wall_ns);
                }
            }
            probes.push(ProbePoint { width: s.width, height: s.height, ns: best_total });
            for (name, ns) in best_stage {
                stage_points.entry(name).or_default().push((s.pixels() as f64, ns as f64));
            }
        }
        let mut calib = Calibration::fit(probes, det.engine().name(), det.n_workers())?;
        calib.stages = stage_points
            .into_iter()
            .filter(|(_, pts)| pts.len() == shapes.len())
            .map(|(stage, pts)| {
                let (a, b) = fit_line(&pts);
                StageCost { stage, overhead_ns: a.round() as u64, cost_ns_per_pixel: b }
            })
            .collect();
        Ok(calib)
    }

    /// Serialize (schema documented in the [`crate::service`] module).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("format".into(), Json::Num(1.0));
        m.insert("engine".into(), Json::Str(self.engine.clone()));
        m.insert("workers".into(), Json::Num(self.workers as f64));
        m.insert("overhead_ns".into(), Json::Num(self.overhead_ns as f64));
        m.insert("cost_ns_per_pixel".into(), Json::Num(self.cost_ns_per_pixel));
        let stages = self
            .stages
            .iter()
            .map(|s| {
                let mut sm = BTreeMap::new();
                sm.insert("stage".into(), Json::Str(s.stage.clone()));
                sm.insert("overhead_ns".into(), Json::Num(s.overhead_ns as f64));
                sm.insert("cost_ns_per_pixel".into(), Json::Num(s.cost_ns_per_pixel));
                Json::Obj(sm)
            })
            .collect();
        m.insert("stages".into(), Json::Arr(stages));
        let probes = self
            .probes
            .iter()
            .map(|p| {
                let mut pm = BTreeMap::new();
                pm.insert("width".into(), Json::Num(p.width as f64));
                pm.insert("height".into(), Json::Num(p.height as f64));
                pm.insert("ns".into(), Json::Num(p.ns as f64));
                Json::Obj(pm)
            })
            .collect();
        m.insert("probes".into(), Json::Arr(probes));
        Json::Obj(m)
    }

    pub fn to_json_string(&self) -> String {
        self.to_json().dump()
    }

    /// Parse + validate a calibration document. `overhead_ns` and
    /// `cost_ns_per_pixel` are required and must be finite and >= 0;
    /// `engine`, `workers` and `probes` are optional provenance. A
    /// `format` other than 1 (or absent) is rejected so future schema
    /// revisions fail loudly instead of loading under v1 semantics.
    pub fn from_json(text: &str) -> Result<Calibration> {
        let j = Json::parse(text)?;
        if let Some(f) = j.get("format").and_then(Json::as_f64) {
            if f != 1.0 {
                return Err(Error::Config(format!(
                    "calibration: unsupported format {f} (this build reads format 1)"
                )));
            }
        }
        let num = |key: &str| -> Result<f64> {
            let v = j
                .get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| Error::Config(format!("calibration: missing/invalid `{key}`")))?;
            if !(v.is_finite() && v >= 0.0) {
                return Err(Error::Config(format!(
                    "calibration: `{key}` must be finite and >= 0, got {v}"
                )));
            }
            Ok(v)
        };
        let overhead_ns = num("overhead_ns")? as u64;
        let cost_ns_per_pixel = num("cost_ns_per_pixel")?;
        let mut stages = Vec::new();
        if let Some(arr) = j.get("stages").and_then(Json::as_arr) {
            for (k, s) in arr.iter().enumerate() {
                let stage = s
                    .get("stage")
                    .and_then(Json::as_str)
                    .ok_or_else(|| {
                        Error::Config(format!("calibration stage {k}: missing `stage`"))
                    })?
                    .to_string();
                let field = |name: &str| -> Result<f64> {
                    let v = s.get(name).and_then(Json::as_f64).ok_or_else(|| {
                        Error::Config(format!(
                            "calibration stage `{stage}`: missing/invalid `{name}`"
                        ))
                    })?;
                    if !(v.is_finite() && v >= 0.0) {
                        return Err(Error::Config(format!(
                            "calibration stage `{stage}`: `{name}` must be finite and >= 0"
                        )));
                    }
                    Ok(v)
                };
                stages.push(StageCost {
                    overhead_ns: field("overhead_ns")? as u64,
                    cost_ns_per_pixel: field("cost_ns_per_pixel")?,
                    stage,
                });
            }
        }
        let mut probes = Vec::new();
        if let Some(arr) = j.get("probes").and_then(Json::as_arr) {
            for (k, p) in arr.iter().enumerate() {
                let field = |name: &str| -> Result<f64> {
                    p.get(name).and_then(Json::as_f64).ok_or_else(|| {
                        Error::Config(format!("calibration probe {k}: missing/invalid `{name}`"))
                    })
                };
                probes.push(ProbePoint {
                    width: field("width")? as usize,
                    height: field("height")? as usize,
                    ns: field("ns")? as u64,
                });
            }
        }
        Ok(Calibration {
            engine: j.get("engine").and_then(Json::as_str).unwrap_or("").to_string(),
            workers: j.get("workers").and_then(Json::as_usize).unwrap_or(0),
            overhead_ns,
            cost_ns_per_pixel,
            stages,
            probes,
        })
    }

    /// [`Calibration::from_json`] over a file.
    pub fn from_json_file(path: &Path) -> Result<Calibration> {
        Calibration::from_json(&std::fs::read_to_string(path)?)
    }

    /// Write the JSON document to `path`.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json_string())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(w: usize, h: usize, ns: u64) -> ProbePoint {
        ProbePoint { width: w, height: h, ns }
    }

    #[test]
    fn fit_recovers_a_linear_model() {
        // ns = 50_000 + 3 * px, exactly.
        let probes: Vec<ProbePoint> = [(64, 64), (128, 128), (256, 256)]
            .iter()
            .map(|&(w, h)| point(w, h, 50_000 + 3 * (w * h) as u64))
            .collect();
        let c = Calibration::fit(probes, "patterns", 4).unwrap();
        assert!((c.overhead_ns as i64 - 50_000).abs() <= 1, "overhead {}", c.overhead_ns);
        assert!((c.cost_ns_per_pixel - 3.0).abs() < 1e-6, "slope {}", c.cost_ns_per_pixel);
        assert_eq!(c.service_ns(10_000), c.overhead_ns + 30_000);
    }

    #[test]
    fn fit_single_shape_goes_through_the_origin() {
        let c = Calibration::fit(vec![point(100, 100, 40_000)], "serial", 1).unwrap();
        assert_eq!(c.overhead_ns, 0);
        assert!((c.cost_ns_per_pixel - 4.0).abs() < 1e-9);
    }

    #[test]
    fn fit_clamps_unphysical_slopes_and_intercepts() {
        // Decreasing cost with size -> slope clamps to 0, flat mean cost.
        let c = Calibration::fit(
            vec![point(64, 64, 90_000), point(256, 256, 10_000)],
            "patterns",
            2,
        )
        .unwrap();
        assert_eq!(c.cost_ns_per_pixel, 0.0);
        assert_eq!(c.overhead_ns, 50_000);
        // Negative intercept (tiny fixed cost) -> refit through origin.
        let c2 = Calibration::fit(
            vec![point(64, 64, 1_000), point(256, 256, 300_000)],
            "patterns",
            2,
        )
        .unwrap();
        assert_eq!(c2.overhead_ns, 0);
        assert!(c2.cost_ns_per_pixel > 0.0);
        assert!(Calibration::fit(Vec::new(), "patterns", 1).is_err());
    }

    #[test]
    fn json_roundtrip_preserves_the_model() {
        let c = Calibration {
            engine: "tiled".into(),
            workers: 3,
            overhead_ns: 120_000,
            cost_ns_per_pixel: 3.5,
            stages: vec![
                StageCost { stage: "front".into(), overhead_ns: 90_000, cost_ns_per_pixel: 3.0 },
                StageCost {
                    stage: "hysteresis".into(),
                    overhead_ns: 10_000,
                    cost_ns_per_pixel: 0.4,
                },
            ],
            probes: vec![point(96, 96, 152_256)],
        };
        let back = Calibration::from_json(&c.to_json_string()).unwrap();
        assert_eq!(back.engine, "tiled");
        assert_eq!(back.workers, 3);
        assert_eq!(back.overhead_ns, 120_000);
        assert!((back.cost_ns_per_pixel - 3.5).abs() < 1e-12);
        assert_eq!(back.stages, c.stages);
        assert_eq!(back.probes, c.probes);
    }

    #[test]
    fn stage_service_sums_only_complete_fits() {
        let c = Calibration {
            engine: "patterns".into(),
            workers: 2,
            overhead_ns: 100_000,
            cost_ns_per_pixel: 4.0,
            stages: vec![
                StageCost { stage: "threshold".into(), overhead_ns: 1_000, cost_ns_per_pixel: 1.0 },
                StageCost {
                    stage: "hysteresis".into(),
                    overhead_ns: 2_000,
                    cost_ns_per_pixel: 0.5,
                },
            ],
            probes: Vec::new(),
        };
        assert_eq!(
            c.stage_service_ns(&["threshold", "hysteresis"], 1_000),
            Some(1_000 + 1_000 + 2_000 + 500)
        );
        // A stage with no fit voids the sum — the caller must fall back.
        assert_eq!(c.stage_service_ns(&["gaussian", "threshold"], 1_000), None);
        assert_eq!(c.stage_service_ns(&[], 1_000), Some(0));
    }

    #[test]
    fn probe_fits_per_stage_models() {
        let det = Detector::builder().workers(1).build().unwrap();
        let shapes =
            [Shape { width: 48, height: 32 }, Shape { width: 64, height: 64 }];
        let c = Calibration::probe(&det, &shapes, 1).unwrap();
        assert_eq!(c.probes.len(), 2);
        assert!(!c.stages.is_empty(), "per-stage fits must exist");
        // The default Patterns engine runs unfused, so every stage span
        // gets its own fit, and the re-threshold stage set is coverable.
        for name in ["pad", "gaussian", "sobel", "nms", "threshold", "hysteresis"] {
            assert!(
                c.stages.iter().any(|s| s.stage == name),
                "missing per-stage fit for {name}"
            );
        }
        assert!(c.stage_service_ns(&["threshold", "hysteresis"], 48 * 32).is_some());
    }

    #[test]
    fn from_json_rejects_bad_documents() {
        assert!(Calibration::from_json("{}").is_err());
        assert!(Calibration::from_json(r#"{"overhead_ns": 1}"#).is_err());
        assert!(
            Calibration::from_json(r#"{"overhead_ns": -5, "cost_ns_per_pixel": 1}"#).is_err()
        );
        assert!(
            Calibration::from_json(r#"{"overhead_ns": 1, "cost_ns_per_pixel": 1e999}"#).is_err()
        );
        // A future schema revision is rejected, not misread as v1.
        assert!(Calibration::from_json(
            r#"{"format": 2, "overhead_ns": 1, "cost_ns_per_pixel": 1}"#
        )
        .is_err());
        // Minimal hand-written model is accepted.
        let c = Calibration::from_json(r#"{"overhead_ns": 1000, "cost_ns_per_pixel": 2}"#)
            .unwrap();
        assert_eq!(c.service_ns(10), 1020);
    }

    #[test]
    fn probe_measures_a_real_detector() {
        let det = Detector::builder().workers(1).build().unwrap();
        let c = Calibration::probe(&det, &[Shape { width: 48, height: 32 }], 1).unwrap();
        assert_eq!(c.probes.len(), 1);
        assert!(c.probes[0].ns > 0, "probe must measure real work");
        assert!(c.service_ns(48 * 32) > 0);
    }
}
