//! The serving tier's two clocks.
//!
//! [`ClockMode::Virtual`] replays a trace in modeled time: lane
//! occupancy advances by the service-cost model and the report is
//! byte-identical for the same trace + seed regardless of host load —
//! the testable, predictive mode.
//!
//! [`ClockMode::Wall`] runs the same admission → batch → lane pipeline
//! against real worker threads and a monotonic clock: arrivals are paced
//! to their trace offsets, lanes drain a shared dispatch channel, and
//! every latency in the report is a measured wall-clock quantity. This
//! is the ground truth the calibrated virtual model is validated
//! against (see [`crate::service::calibrate`]).

use std::time::{Duration, Instant};

/// Which clock drives the serving event loop.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ClockMode {
    /// Deterministic modeled time (the default).
    #[default]
    Virtual,
    /// Real threads + monotonic time.
    Wall,
}

impl ClockMode {
    /// Parse the `--clock` config value.
    pub fn parse(s: &str) -> Option<ClockMode> {
        match s {
            "virtual" => Some(ClockMode::Virtual),
            "wall" | "real" | "realtime" => Some(ClockMode::Wall),
            _ => None,
        }
    }

    /// The name echoed in the serving report's `clock` field.
    pub fn name(&self) -> &'static str {
        match self {
            ClockMode::Virtual => "virtual",
            ClockMode::Wall => "wall",
        }
    }
}

/// Monotonic time since an epoch fixed at serve start, in nanoseconds —
/// the wall driver's analogue of the virtual driver's `now` counter.
/// Copyable so every lane thread carries the same epoch.
#[derive(Clone, Copy, Debug)]
pub struct WallClock {
    epoch: Instant,
}

impl WallClock {
    pub fn start() -> WallClock {
        WallClock { epoch: Instant::now() }
    }

    /// Nanoseconds elapsed since [`WallClock::start`].
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Sleep until the clock reads at least `t_ns` (no-op if it already
    /// does). Loops because `thread::sleep` may wake early.
    pub fn sleep_until(&self, t_ns: u64) {
        loop {
            let now = self.now_ns();
            if now >= t_ns {
                return;
            }
            std::thread::sleep(Duration::from_nanos(t_ns - now));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parse_roundtrip() {
        for m in [ClockMode::Virtual, ClockMode::Wall] {
            assert_eq!(ClockMode::parse(m.name()), Some(m));
        }
        assert_eq!(ClockMode::parse("real"), Some(ClockMode::Wall));
        assert_eq!(ClockMode::parse("sundial"), None);
        assert_eq!(ClockMode::default(), ClockMode::Virtual);
    }

    #[test]
    fn wall_clock_is_monotone() {
        let c = WallClock::start();
        let a = c.now_ns();
        std::thread::sleep(Duration::from_millis(1));
        assert!(c.now_ns() > a);
    }

    #[test]
    fn sleep_until_reaches_the_deadline() {
        let c = WallClock::start();
        c.sleep_until(2_000_000); // 2 ms
        assert!(c.now_ns() >= 2_000_000);
        // Past deadlines return immediately.
        let before = c.now_ns();
        c.sleep_until(1);
        assert!(c.now_ns() - before < 1_000_000_000);
    }
}
