//! The serving event loop: admission → batching → sharded detector
//! lanes → SLO report.
//!
//! Scheduling runs in **virtual time**. Arrivals carry virtual
//! timestamps, lane occupancy advances by a deterministic service-cost
//! model (fixed per-dispatch overhead + per-pixel cost), and every
//! latency in the report is a virtual quantity — so replaying a trace
//! with the same seed produces a byte-identical report regardless of
//! host load. This extends the repo's determinism rule (same edge map
//! from every engine) to the *scheduling* layer, which is what makes
//! serving behaviour testable at all.
//!
//! Real compute still happens: every dispatched request runs the real
//! detector owned by its lane, and the report carries the exactly
//! reproducible edge totals. Only *time* is modeled.

use std::collections::VecDeque;

use crate::canny::{CannyParams, Engine};
use crate::config::RunConfig;
use crate::coordinator::planner::Workload;
use crate::coordinator::{CpuTopology, Detector, Planner};
use crate::error::Result;
use crate::image::synth::generate;
use crate::service::batcher::{Batcher, FormedBatch};
use crate::service::queue::AdmissionQueue;
use crate::service::request::{Shape, Trace};
use crate::service::slo::{LaneReport, LatencyStats, ServeReport};

/// Virtual per-dispatch overhead (scheduling + lane wake-up), ns.
pub const DEFAULT_BATCH_OVERHEAD_NS: u64 = 100_000;
/// Virtual per-pixel service cost, ns (≈ 250 Mpix/s per lane).
pub const DEFAULT_COST_NS_PER_PIXEL: u64 = 4;

/// Resolved serving options (see the `RunConfig` serve keys).
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Worker lanes, each owning a detector.
    pub lanes: usize,
    /// Admission bound: max admitted-but-undispatched requests.
    pub queue_depth: usize,
    /// Batcher max-delay window (virtual ns).
    pub batch_window_ns: u64,
    /// Max requests coalesced into one dispatch.
    pub max_batch: usize,
    /// SLO target on aggregate p99 end-to-end latency (virtual ns).
    pub slo_p99_ns: u64,
    /// Per-request pixel budget (0 = unlimited); larger requests are
    /// rejected at admission with an `oversize` reason.
    pub max_pixels: usize,
    /// Run the real detector for every request (edge totals in the
    /// report). Disable for pure scheduling studies and fast tests.
    pub execute: bool,
    /// Virtual service-cost model.
    pub batch_overhead_ns: u64,
    pub cost_ns_per_pixel: u64,
    /// Worker threads per lane (0 = split host CPUs evenly over lanes).
    pub workers_per_lane: usize,
    /// Base detection parameters (the planner may adapt tile/grain).
    pub params: CannyParams,
    /// Echoed into the report for provenance.
    pub seed: u64,
}

impl ServeOptions {
    pub fn from_config(cfg: &RunConfig) -> ServeOptions {
        ServeOptions {
            lanes: cfg.lanes.max(1),
            queue_depth: cfg.queue_depth.max(1),
            batch_window_ns: cfg.batch_window_us.saturating_mul(1_000),
            max_batch: cfg.batch_max.max(1),
            slo_p99_ns: (cfg.slo_p99_ms.max(0.0) * 1e6) as u64,
            max_pixels: cfg.max_pixels,
            execute: true,
            batch_overhead_ns: DEFAULT_BATCH_OVERHEAD_NS,
            cost_ns_per_pixel: DEFAULT_COST_NS_PER_PIXEL,
            workers_per_lane: 0,
            params: cfg.params,
            seed: cfg.seed,
        }
    }
}

struct Lane {
    det: Option<Detector>,
    busy_until_ns: u64,
    busy_ns: u64,
    batches: u64,
    requests: u64,
    edge_pixels: u64,
    latency: LatencyStats,
}

/// Plan the per-lane detector: the GCP kernel layer picks engine and
/// parameters for the trace's dominant shape at batch depth; workers
/// are the host CPUs sharded evenly across lanes. XLA lanes are pinned
/// off for now (artifact-backed lanes are a later PR).
fn plan_lanes(trace: &Trace, opts: &ServeOptions) -> (Engine, usize, CannyParams) {
    let shape = trace.dominant_shape().unwrap_or(Shape { width: 128, height: 128 });
    let planner = Planner::new(CpuTopology::detect()).with_xla(false);
    let plan = planner.plan(
        Workload { image_w: shape.width, image_h: shape.height, batch: opts.max_batch },
        &opts.params,
    );
    let workers = if opts.workers_per_lane > 0 {
        opts.workers_per_lane
    } else {
        (plan.workers / opts.lanes).max(1)
    };
    (plan.engine, workers, plan.params)
}

/// Replay `trace` through the serving tier and return the SLO report.
///
/// Event loop invariants (all in virtual time, all deterministic):
/// * at one instant, lane completions free lanes first, then expired
///   batch windows close, then arrivals are admitted, then dispatch —
///   a lane freed at `t` can take a batch formed at `t`;
/// * dispatch is FIFO over closed batches onto the lowest-numbered
///   idle lane;
/// * admission is decided *at arrival* against the current waiting-room
///   occupancy — a full room rejects immediately (open-loop clients
///   don't retry).
pub fn serve(label: &str, trace: &Trace, opts: &ServeOptions) -> Result<ServeReport> {
    let (engine, workers_per_lane, params) = plan_lanes(trace, opts);
    let mut lanes: Vec<Lane> = Vec::with_capacity(opts.lanes);
    for _ in 0..opts.lanes {
        let det = if opts.execute {
            Some(
                Detector::builder()
                    .engine(engine)
                    .workers(workers_per_lane)
                    .params(params)
                    .build()?,
            )
        } else {
            None
        };
        lanes.push(Lane {
            det,
            busy_until_ns: 0,
            busy_ns: 0,
            batches: 0,
            requests: 0,
            edge_pixels: 0,
            latency: LatencyStats::new(),
        });
    }

    let mut queue = AdmissionQueue::new(opts.queue_depth);
    if opts.max_pixels > 0 {
        queue = queue.with_max_pixels(opts.max_pixels);
    }
    let mut batcher = Batcher::new(opts.batch_window_ns, opts.max_batch);
    let mut ready: VecDeque<FormedBatch> = VecDeque::new();
    let mut total_latency = LatencyStats::new();
    let mut queue_wait = LatencyStats::new();
    let mut completed = 0u64;
    let mut makespan_ns = 0u64;
    let mut next = 0usize; // arrival cursor into trace.requests
    let mut now = 0u64;

    loop {
        // Dispatch everything possible at `now`: FIFO batches onto the
        // lowest-numbered idle lane.
        while !ready.is_empty() {
            let Some(idx) = lanes.iter().position(|l| l.busy_until_ns <= now) else {
                break;
            };
            let batch = ready.pop_front().expect("checked non-empty");
            let service_ns = opts
                .batch_overhead_ns
                .saturating_add(opts.cost_ns_per_pixel.saturating_mul(batch.pixels() as u64));
            let dispatch_ns = now;
            let complete_ns = now + service_ns;
            queue.release(batch.len());
            makespan_ns = makespan_ns.max(complete_ns);
            let lane = &mut lanes[idx];
            lane.busy_until_ns = complete_ns;
            lane.busy_ns += service_ns;
            lane.batches += 1;
            for req in &batch.requests {
                lane.requests += 1;
                completed += 1;
                queue_wait.record(dispatch_ns - req.arrival_ns);
                total_latency.record(complete_ns - req.arrival_ns);
                lane.latency.record(complete_ns - req.arrival_ns);
                if let Some(det) = &lane.det {
                    let img = generate(req.scene, req.width, req.height);
                    let edges = det.detect_default(&img)?;
                    lane.edge_pixels += edges.count_edges() as u64;
                }
            }
        }

        // Next event: arrival, batch-window deadline, or (if work is
        // waiting to dispatch) the earliest lane-free time.
        let mut t = u64::MAX;
        if next < trace.requests.len() {
            t = t.min(trace.requests[next].arrival_ns);
        }
        if let Some(d) = batcher.next_deadline() {
            t = t.min(d);
        }
        if !ready.is_empty() {
            if let Some(free) =
                lanes.iter().map(|l| l.busy_until_ns).filter(|&b| b > now).min()
            {
                t = t.min(free);
            }
        }
        if t == u64::MAX {
            break;
        }
        now = now.max(t);

        for b in batcher.expire(now) {
            ready.push_back(b);
        }
        while next < trace.requests.len() && trace.requests[next].arrival_ns <= now {
            let req = trace.requests[next];
            next += 1;
            // Rejections are final (and counted inside the queue);
            // admitted requests go to the batcher, which may close a
            // batch at max fill.
            if queue.try_admit(req.pixels()).is_ok() {
                if let Some(b) = batcher.push(req, req.arrival_ns) {
                    ready.push_back(b);
                }
            }
        }
    }
    debug_assert_eq!(batcher.pending(), 0);
    debug_assert_eq!(queue.occupancy(), 0);

    let edge_pixels = lanes.iter().map(|l| l.edge_pixels).sum();
    let lane_reports = lanes
        .iter()
        .enumerate()
        .map(|(i, l)| LaneReport {
            lane: i,
            requests: l.requests,
            batches: l.batches,
            busy_ns: l.busy_ns,
            latency: l.latency.summary(),
        })
        .collect();
    Ok(ServeReport {
        label: label.to_string(),
        seed: opts.seed,
        engine: engine.name().to_string(),
        workers_per_lane,
        offered: trace.len() as u64,
        admitted: queue.admitted,
        rejected_full: queue.rejected_full,
        rejected_oversize: queue.rejected_oversize,
        completed,
        queue_depth: queue.depth(),
        queue_high_water: queue.high_water,
        batch_window_ns: opts.batch_window_ns,
        max_batch: opts.max_batch,
        batches_formed: batcher.batches_formed,
        makespan_ns,
        edge_pixels,
        latency: total_latency.summary(),
        queue_wait: queue_wait.summary(),
        lanes: lane_reports,
        slo_target_p99_ns: opts.slo_p99_ns,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts() -> ServeOptions {
        let mut o = ServeOptions::from_config(&RunConfig::default());
        o.execute = false;
        o
    }

    #[test]
    fn conservation_offered_equals_completed_plus_rejected() {
        let trace = Trace::synthetic(120, 11, 5_000.0);
        let report = serve("t", &trace, &opts()).unwrap();
        assert_eq!(report.offered, 120);
        assert_eq!(report.offered, report.completed + report.rejected());
        assert_eq!(report.admitted, report.completed);
        assert!(report.makespan_ns > 0);
        assert!(report.batches_formed > 0);
        assert!(report.queue_high_water >= 1);
    }

    #[test]
    fn lanes_share_the_load() {
        let mut o = opts();
        o.lanes = 3;
        // Arrival pressure high enough that one lane cannot keep up.
        let trace = Trace::synthetic(300, 5, 50_000.0);
        let report = serve("t", &trace, &o).unwrap();
        assert_eq!(report.lanes.len(), 3);
        let active = report.lanes.iter().filter(|l| l.requests > 0).count();
        assert!(active >= 2, "only {active} lanes took work");
        assert_eq!(
            report.lanes.iter().map(|l| l.requests).sum::<u64>(),
            report.completed
        );
    }

    #[test]
    fn tiny_queue_rejects_under_burst() {
        let mut o = opts();
        o.queue_depth = 2;
        o.lanes = 1;
        // Very high rate: arrivals bunch faster than one lane drains.
        let trace = Trace::synthetic(100, 3, 1_000_000.0);
        let report = serve("t", &trace, &o).unwrap();
        assert!(report.rejected_full > 0, "expected backpressure rejections");
        assert!(report.queue_high_water <= 2);
    }

    #[test]
    fn empty_trace_is_a_noop_report() {
        let report = serve("t", &Trace::default(), &opts()).unwrap();
        assert_eq!(report.offered, 0);
        assert_eq!(report.makespan_ns, 0);
        assert_eq!(report.throughput_rps(), 0.0);
        assert!(report.slo_met());
    }

    #[test]
    fn wider_window_forms_fewer_batches() {
        let base = Trace::synthetic(200, 9, 20_000.0);
        let mut narrow = opts();
        narrow.batch_window_ns = 0;
        let mut wide = opts();
        wide.batch_window_ns = 10_000_000; // 10 ms
        let rn = serve("narrow", &base, &narrow).unwrap();
        let rw = serve("wide", &base, &wide).unwrap();
        assert!(
            rw.batches_formed < rn.batches_formed,
            "wide {} vs narrow {}",
            rw.batches_formed,
            rn.batches_formed
        );
        assert!(rw.mean_batch_fill() > rn.mean_batch_fill());
    }
}
