//! The serving event loop: admission → batching → sharded detector
//! lanes → SLO report, driven by either of two clocks.
//!
//! The **virtual** driver (default) schedules in modeled time: arrivals
//! carry virtual timestamps, lane occupancy advances by a deterministic
//! service-cost model (per-dispatch overhead + per-pixel cost, either
//! the synthetic defaults or a fitted [`Calibration`]), and every
//! latency in the report is a virtual quantity — replaying a trace with
//! the same seed produces a byte-identical report regardless of host
//! load. Real compute still happens when `execute` is on; only *time*
//! is modeled.
//!
//! The **wall** driver runs the identical admission/batching front half
//! against real worker threads and a monotonic clock: arrivals are
//! paced to their trace offsets, each lane is a thread draining a
//! shared dispatch channel, and latencies are measured. With `execute`
//! off, a wall lane occupies itself by sleeping the modeled service
//! time instead, so scheduling studies work without compute. A wall run
//! drains gracefully on SIGINT (see [`install_sigint_drain`]): pending
//! arrivals are abandoned, admitted requests complete, and the report
//! carries `"interrupted": true`.
//!
//! Both drivers share the clock-agnostic [`Intake`] core (admission +
//! coalescing) and the report assembly, so the virtual mode is a true
//! model of the wall mode — which is what makes calibration
//! ([`crate::service::calibrate`]) meaningful.
//!
//! ## Request kinds and the shared artifact cache
//!
//! Requests carry a [`RequestKind`] selecting which pipeline span runs
//! (a [`crate::canny::StagePlan`] at the serving boundary):
//!
//! * `full` — the whole pipeline (the classic path);
//! * `front-only` — stop after NMS and warm the **shared**
//!   [`crate::cache::ArtifactCache`] with the suppressed-magnitude map
//!   under its content-addressed key;
//! * `re-threshold {lo, hi}` — re-run only Threshold + Hysteresis from
//!   the cached map. On a cache hit, Gaussian/Sobel/NMS never run —
//!   the report's `stages` section proves it.
//!
//! The cache is one `Arc<ArtifactCache>` shared by *every* lane (and
//! any stream executor handed the same handle): a front-only request
//! served on lane 0 warms re-thresholds on lane 3, and identical
//! content deduplicates across clients. Under the wall clock the lanes
//! exercise real cross-shard contention; under the virtual clock the
//! single-threaded replay keeps cache state — and so the report's
//! `cache` section — byte-identical across runs.
//!
//! The virtual clock charges each kind only its stage set: per-stage
//! calibration fits when installed, synthetic fractions of the full
//! cost otherwise (re-threshold is modeled as a cache hit; the wall
//! driver measures reality). Kinds that consult the cache are
//! additionally charged a modeled lookup cost
//! ([`CACHE_LOOKUP_OVERHEAD_NS`] plus a per-pixel hash term), so the
//! deterministic replay stays honest about the content digest the real
//! path computes.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::cache::{ArtifactCache, ArtifactKey, CacheConfig, CacheSnapshot, CacheTier};
use crate::canny::{Artifact, CannyParams, Engine, StageKind};
use crate::config::RunConfig;
use crate::coordinator::planner::Workload;
use crate::coordinator::{CpuTopology, Detector, Planner};
use crate::error::{Error, Result};
use crate::image::synth::generate;
use crate::image::ImageF32;
use crate::obs::{
    content_digest, modeled_stage_durs, request_spans, AnomalyMonitor, FaultManager,
    HealthTracker, ObsEndpoint, OverloadPolicy, ShedDecision, SnapshotEngine, Telemetry,
    TickInputs, TraceCollector, TraceId, TraceSampler, WallSnapshotter,
};
use crate::scheduler::PoolStats;
use crate::service::batcher::{Batcher, FormedBatch};
use crate::service::calibrate::{Calibration, DEFAULT_PROBE_SHAPES, PROBE_REPEATS};
use crate::service::clock::{ClockMode, WallClock};
use crate::service::queue::AdmissionQueue;
use crate::service::request::{Request, RequestKind, Shape, Trace};
use crate::service::slo::{CostModel, LaneReport, LatencyStats, ServeReport, SloWindow, WindowReport};

/// Virtual per-dispatch overhead (scheduling + lane wake-up), ns —
/// used when no [`Calibration`] is installed.
pub const DEFAULT_BATCH_OVERHEAD_NS: u64 = 100_000;
/// Virtual per-pixel service cost, ns (≈ 250 Mpix/s per lane) — used
/// when no [`Calibration`] is installed.
pub const DEFAULT_COST_NS_PER_PIXEL: u64 = 4;

/// Synthetic fallback: front-only per-pixel cost as a percentage of the
/// full pipeline's (the front is most of the work; hysteresis and the
/// final threshold are cheap). Used only when no per-stage calibration
/// covers the kind's stage set.
pub const SYNTH_FRONT_PCT: u64 = 85;
/// Synthetic fallback: re-threshold (threshold + hysteresis only)
/// per-pixel cost as a percentage of the full pipeline's.
pub const SYNTH_RETHRESHOLD_PCT: u64 = 15;

/// The stage spans a front-only request executes (per-stage
/// calibration lookup key).
const FRONT_STAGES: &[&str] = &["pad", "gaussian", "sobel", "nms"];
/// The stage spans a re-threshold request executes on a cache hit.
const RETHRESHOLD_STAGES: &[&str] = &["threshold", "hysteresis"];
/// The stage spans a full request executes (the whole pipeline).
const FULL_STAGES: &[&str] = &["pad", "gaussian", "sobel", "nms", "threshold", "hysteresis"];

/// The stage-span names `kind` executes — the skeleton a trace models
/// per-stage durations over when none were measured (virtual drivers,
/// execute-off runs, the cluster worker's modeled clock). Re-threshold
/// is modeled as a cache hit, matching
/// [`ServeOptions::service_ns_kind`].
pub fn kind_stage_names(kind: RequestKind) -> &'static [&'static str] {
    match kind {
        RequestKind::Full => FULL_STAGES,
        RequestKind::FrontOnly => FRONT_STAGES,
        RequestKind::ReThreshold { .. } => RETHRESHOLD_STAGES,
    }
}

/// Modeled fixed cost of one shared-cache consult (shard probe + LRU
/// touch), charged by the virtual clock for kinds that use the cache.
pub const CACHE_LOOKUP_OVERHEAD_NS: u64 = 2_000;
/// Modeled content-digest throughput: the word-folding FNV digest
/// ([`crate::cache::KeyHasher`]) costs two dependent multiply chains
/// per pixel, charged as two pixels per nanosecond (~8 GB/s).
pub const CACHE_HASH_PIXELS_PER_NS: u64 = 2;

/// How often a wall-clock arrival sleep re-checks the interrupt flag.
const INTERRUPT_POLL_NS: u64 = 20_000_000; // 20 ms

/// Resolved serving options (see the `RunConfig` serve keys).
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Worker lanes, each owning a detector.
    pub lanes: usize,
    /// Admission bound: max admitted-but-undispatched requests.
    pub queue_depth: usize,
    /// Batcher max-delay window (ns, in the active clock).
    pub batch_window_ns: u64,
    /// Max requests coalesced into one dispatch.
    pub max_batch: usize,
    /// SLO target on aggregate p99 end-to-end latency (ns).
    pub slo_p99_ns: u64,
    /// Per-request pixel budget (0 = unlimited); larger requests are
    /// rejected at admission with an `oversize` reason.
    pub max_pixels: usize,
    /// Run the real detector for every request (edge totals in the
    /// report). Disable for pure scheduling studies and fast tests.
    pub execute: bool,
    /// Synthetic service-cost constants (used unless `calibration` is
    /// set).
    pub batch_overhead_ns: u64,
    pub cost_ns_per_pixel: u64,
    /// Fitted cost model; replaces the synthetic constants when set.
    pub calibration: Option<Calibration>,
    /// Which clock drives the event loop.
    pub clock: ClockMode,
    /// Worker threads per lane (0 = split host CPUs evenly over lanes).
    pub workers_per_lane: usize,
    /// Shared artifact-cache tier configuration (budget 0 disables it:
    /// every re-threshold recomputes the front).
    pub cache: CacheConfig,
    /// An externally-owned cache to serve from instead of building a
    /// fresh one per run — how a process shares one tier between
    /// serving and streaming (see [`crate::stream::StreamOptions`]).
    pub shared_cache: Option<Arc<ArtifactCache>>,
    /// Base detection parameters (the planner may adapt tile/grain).
    pub params: CannyParams,
    /// When set, a raised flag drains a wall-clock run gracefully
    /// (see [`install_sigint_drain`]).
    pub interrupt: Option<&'static AtomicBool>,
    /// Echoed into the report for provenance.
    pub seed: u64,
    /// Telemetry JSONL sink (`--telemetry-log`); `None` disables the
    /// ops plane's snapshot stream (the registry still runs — it is
    /// how the report's overload section is fed).
    pub telemetry_log: Option<PathBuf>,
    /// Snapshot tick interval in the active clock's nanoseconds
    /// (`--telemetry-interval-ms`).
    pub telemetry_interval_ns: u64,
    /// What to do with new arrivals while the rolling SLO is missed
    /// (`--overload-policy`).
    pub overload_policy: OverloadPolicy,
    /// Rolling SLO window capacity in completions (`--slo-window`).
    pub slo_window: usize,
    /// Health-transition alert sink spec (`--alert-log`): "" disables,
    /// `stderr` streams, anything else is a file path. Transitions are
    /// evaluated on the telemetry tick grid, so alerts work with or
    /// without a `--telemetry-log`.
    pub alert_log: String,
    /// Span sink behind `--trace-log`; `None` disables tracing. Every
    /// admitted request gets a deterministic [`crate::obs::TraceId`]
    /// and a span tree (root / coalesce / queue / service / cache /
    /// stages) written at the end of the run.
    pub trace: Option<Arc<TraceCollector>>,
    /// Tail-based sampling policy (`--trace-sample`): decided per
    /// request *after* completion, when the end-to-end latency is
    /// known, it gates both the span tree entering the trace sink and
    /// the exemplar entering the latency histogram — so every exported
    /// exemplar resolves to a retained trace. The default keeps
    /// everything.
    pub sampler: TraceSampler,
    /// Streaming anomaly detection over the telemetry tick grid
    /// (`--anomaly-sigma`, standard deviations; 0 disables).
    pub anomaly_sigma: f64,
    /// Live snapshot endpoint (`--obs-port`), attached by the CLI so
    /// the run's snapshot engine publishes every line it renders.
    pub obs_endpoint: Option<Arc<ObsEndpoint>>,
}

impl ServeOptions {
    pub fn from_config(cfg: &RunConfig) -> ServeOptions {
        let slo_p99_ns = (cfg.slo_p99_ms.max(0.0) * 1e6) as u64;
        ServeOptions {
            lanes: cfg.lanes.max(1),
            queue_depth: cfg.queue_depth.max(1),
            batch_window_ns: cfg.batch_window_us.saturating_mul(1_000),
            max_batch: cfg.batch_max.max(1),
            slo_p99_ns,
            max_pixels: cfg.max_pixels,
            execute: true,
            batch_overhead_ns: DEFAULT_BATCH_OVERHEAD_NS,
            cost_ns_per_pixel: DEFAULT_COST_NS_PER_PIXEL,
            calibration: None,
            clock: cfg.clock,
            workers_per_lane: 0,
            cache: CacheConfig::from_config(cfg),
            shared_cache: None,
            params: cfg.params,
            interrupt: None,
            seed: cfg.seed,
            telemetry_log: if cfg.telemetry_log.is_empty() {
                None
            } else {
                Some(PathBuf::from(&cfg.telemetry_log))
            },
            telemetry_interval_ns: (cfg.telemetry_interval_ms.max(0.0) * 1e6) as u64,
            overload_policy: cfg.overload_policy,
            slo_window: cfg.slo_window.max(1),
            alert_log: cfg.alert_log.clone(),
            trace: TraceCollector::from_spec(&cfg.trace_log),
            // `RunConfig::validate` rejects malformed specs; the
            // keep-everything fallback only covers unvalidated configs.
            sampler: TraceSampler::from_spec(&cfg.trace_sample, slo_p99_ns)
                .unwrap_or_else(|_| TraceSampler::all()),
            anomaly_sigma: cfg.anomaly_sigma,
            obs_endpoint: None,
        }
    }

    /// Modeled service cost of one full-pipeline dispatch: the
    /// calibration when installed, else the synthetic constants.
    pub fn service_ns(&self, pixels: usize) -> u64 {
        match &self.calibration {
            Some(c) => c.service_ns(pixels),
            None => self
                .batch_overhead_ns
                .saturating_add(self.cost_ns_per_pixel.saturating_mul(pixels as u64)),
        }
    }

    /// Is the cache tier this run will actually serve from enabled?
    /// The injected [`ServeOptions::shared_cache`] takes precedence
    /// over the run's own [`CacheConfig`] — exactly mirroring which
    /// cache the execution path uses — so the modeled lookup charge and
    /// the real digest/probe can never disagree.
    pub fn cache_enabled(&self) -> bool {
        match &self.shared_cache {
            Some(shared) => shared.enabled(),
            None => self.cache.enabled(),
        }
    }

    /// Modeled cost of one shared-cache consult for a request of
    /// `pixels` pixels: the content digest walks every pixel, plus a
    /// fixed shard-probe cost. Zero when the effective cache tier is
    /// disabled — the real path skips the hash too.
    pub fn cache_lookup_ns(&self, pixels: usize) -> u64 {
        if !self.cache_enabled() {
            return 0;
        }
        CACHE_LOOKUP_OVERHEAD_NS.saturating_add(pixels as u64 / CACHE_HASH_PIXELS_PER_NS)
    }

    /// Modeled service cost of one dispatch of `kind`: full dispatches
    /// use the end-to-end model; partial kinds use the per-stage
    /// calibration fits when they cover the kind's stage set, else a
    /// synthetic fraction of the full per-pixel cost, plus the modeled
    /// cache-lookup cost (those kinds hash content and probe a shard).
    /// Re-threshold is modeled as a cache hit (the wall driver measures
    /// misses).
    pub fn service_ns_kind(&self, kind: RequestKind, pixels: usize) -> u64 {
        let fraction = |pct: u64| {
            self.batch_overhead_ns.saturating_add(
                self.cost_ns_per_pixel
                    .saturating_mul(pixels as u64)
                    .saturating_mul(pct)
                    / 100,
            )
        };
        let base = match kind {
            RequestKind::Full => return self.service_ns(pixels),
            RequestKind::FrontOnly => match &self.calibration {
                Some(c) => c
                    .stage_service_ns(FRONT_STAGES, pixels)
                    .unwrap_or_else(|| c.service_ns(pixels) * SYNTH_FRONT_PCT / 100),
                None => fraction(SYNTH_FRONT_PCT),
            },
            RequestKind::ReThreshold { .. } => match &self.calibration {
                Some(c) => c
                    .stage_service_ns(RETHRESHOLD_STAGES, pixels)
                    .unwrap_or_else(|| c.service_ns(pixels) * SYNTH_RETHRESHOLD_PCT / 100),
                None => fraction(SYNTH_RETHRESHOLD_PCT),
            },
        };
        debug_assert!(kind.uses_artifact_cache());
        base.saturating_add(self.cache_lookup_ns(pixels))
    }

    /// Modeled service cost of one dispatched batch: `n` same-kind
    /// requests totalling `pixels` pixels. The per-pixel terms already
    /// scale with the batch total, but the real path hashes and probes
    /// the cache once *per request*, so cache-using kinds are charged
    /// the fixed probe overhead `n` times, not once.
    pub fn service_ns_batch(&self, kind: RequestKind, pixels: usize, n: usize) -> u64 {
        let base = self.service_ns_kind(kind, pixels);
        if kind.uses_artifact_cache() && self.cache_enabled() && n > 1 {
            base.saturating_add(CACHE_LOOKUP_OVERHEAD_NS.saturating_mul(n as u64 - 1))
        } else {
            base
        }
    }

    fn cost_model(&self) -> CostModel {
        match &self.calibration {
            Some(c) => CostModel::Calibrated(c.clone()),
            None => CostModel::Synthetic {
                overhead_ns: self.batch_overhead_ns,
                cost_ns_per_pixel: self.cost_ns_per_pixel,
            },
        }
    }

    fn interrupted(&self) -> bool {
        self.interrupt.is_some_and(|f| f.load(Ordering::SeqCst))
    }
}

// ---- SIGINT drain -------------------------------------------------------

static SIGINT_DRAIN: AtomicBool = AtomicBool::new(false);

extern "C" fn sigint_handler(_: libc::c_int) {
    SIGINT_DRAIN.store(true, Ordering::SeqCst);
}

/// Install a SIGINT handler that requests a graceful wall-clock serve
/// drain and return the flag to pass as [`ServeOptions::interrupt`].
/// On Ctrl-C the arrival replay stops, admitted requests complete, and
/// [`serve`] returns a partial report with `"interrupted": true`. The
/// flag is re-armed (cleared) on every install, so a process serving
/// multiple runs is not instantly drained by a previous run's Ctrl-C.
pub fn install_sigint_drain() -> &'static AtomicBool {
    SIGINT_DRAIN.store(false, Ordering::SeqCst);
    let handler = sigint_handler as extern "C" fn(libc::c_int);
    // SAFETY: installing a signal handler that only stores to an
    // AtomicBool (async-signal-safe).
    unsafe {
        libc::signal(libc::SIGINT, handler as libc::sighandler_t);
    }
    &SIGINT_DRAIN
}

/// Plan the per-lane detector: the GCP kernel layer picks engine and
/// parameters for the trace's dominant shape at batch depth; workers
/// are the host CPUs sharded evenly across lanes. XLA lanes are pinned
/// off for now (artifact-backed lanes are a later PR).
fn plan_lanes(trace: &Trace, opts: &ServeOptions) -> (Engine, usize, CannyParams) {
    let shape = trace.dominant_shape().unwrap_or(Shape { width: 128, height: 128 });
    let planner = Planner::new(CpuTopology::detect()).with_xla(false);
    let plan = planner.plan(
        Workload { image_w: shape.width, image_h: shape.height, batch: opts.max_batch },
        &opts.params,
    );
    let workers = if opts.workers_per_lane > 0 {
        opts.workers_per_lane
    } else {
        (plan.workers / opts.lanes).max(1)
    };
    (plan.engine, workers, plan.params)
}

fn build_lane_detector(
    engine: Engine,
    workers: usize,
    params: CannyParams,
    execute: bool,
) -> Result<Option<Detector>> {
    if !execute {
        return Ok(None);
    }
    Ok(Some(Detector::builder().engine(engine).workers(workers).params(params).build()?))
}

/// Cap on how many distinct shapes [`calibrate_for`] probes (most
/// frequent first) — bounds `--calibration probe` startup cost on
/// traces with many unique geometries.
pub const MAX_PROBE_SHAPES: usize = 8;

/// Probe a [`Calibration`] matched to how [`serve`] would run `trace`:
/// the same planner decision (engine, workers-per-lane, adapted params)
/// and the trace's own shapes as the probe grid — at most
/// [`MAX_PROBE_SHAPES`], most frequent first (falling back to
/// [`DEFAULT_PROBE_SHAPES`] for an empty trace).
pub fn calibrate_for(trace: &Trace, opts: &ServeOptions) -> Result<Calibration> {
    let (engine, workers_per_lane, params) = plan_lanes(trace, opts);
    let det =
        Detector::builder().engine(engine).workers(workers_per_lane).params(params).build()?;
    let shapes: Vec<Shape> = if trace.is_empty() {
        DEFAULT_PROBE_SHAPES.iter().map(|&(w, h)| Shape { width: w, height: h }).collect()
    } else {
        let mut counts: BTreeMap<Shape, usize> = Default::default();
        for r in &trace.requests {
            *counts.entry(r.shape()).or_insert(0) += 1;
        }
        let distinct = counts.len();
        let mut by_freq: Vec<(Shape, usize)> = counts.into_iter().collect();
        by_freq.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        if distinct > MAX_PROBE_SHAPES {
            eprintln!(
                "calibrate: probing the {MAX_PROBE_SHAPES} most frequent of {distinct} \
                 distinct shapes (per-pixel fit covers the rest)"
            );
        }
        by_freq.into_iter().take(MAX_PROBE_SHAPES).map(|(s, _)| s).collect()
    };
    Calibration::probe(&det, &shapes, PROBE_REPEATS)
}

// ---- Shared artifact cache ----------------------------------------------

/// The cache every lane of this run serves from: the caller-supplied
/// handle when one was injected ([`ServeOptions::shared_cache`], the
/// cross-tier sharing path), else a fresh tier built from the run's
/// [`CacheConfig`].
fn build_cache(opts: &ServeOptions) -> Arc<ArtifactCache> {
    match &opts.shared_cache {
        Some(shared) => Arc::clone(shared),
        None => Arc::new(ArtifactCache::new(opts.cache.clone())),
    }
}

/// Offer a freshly-computed front to the shared tier under the image's
/// content key — the one warm path both the front-only kind and the
/// re-threshold miss use, so their key span and recompute estimate (the
/// calibrated front cost) can never diverge.
fn offer_front(cache: &ArtifactCache, opts: &ServeOptions, img: &ImageF32, nm: ImageF32) {
    cache.offer(
        ArtifactKey::suppressed(img),
        Artifact::Suppressed(nm),
        opts.service_ns_kind(RequestKind::FrontOnly, img.len()),
        CacheTier::Serve,
    );
}

// ---- Clock-agnostic core ------------------------------------------------

/// The front half of the pipeline — admission control + batch
/// coalescing — shared verbatim by both drivers. Drivers feed it
/// timestamps from their own clock and get back dispatch-ready batches.
struct Intake {
    queue: AdmissionQueue,
    batcher: Batcher,
}

impl Intake {
    fn new(opts: &ServeOptions) -> Intake {
        let mut queue = AdmissionQueue::new(opts.queue_depth);
        if opts.max_pixels > 0 {
            queue = queue.with_max_pixels(opts.max_pixels);
        }
        Intake { queue, batcher: Batcher::new(opts.batch_window_ns, opts.max_batch) }
    }

    /// One arrival at `now_ns`: admission is decided immediately
    /// (rejections are final — open-loop clients don't retry); admitted
    /// requests join the batcher, which may close a batch at max fill.
    fn arrive(&mut self, req: Request, now_ns: u64) -> Option<FormedBatch> {
        if self.queue.try_admit(req.pixels()).is_ok() {
            self.batcher.push(req, now_ns)
        } else {
            None
        }
    }

    fn expire(&mut self, now_ns: u64) -> Vec<FormedBatch> {
        self.batcher.expire(now_ns)
    }

    fn next_deadline(&self) -> Option<u64> {
        self.batcher.next_deadline()
    }

    /// A batch left the waiting room (dispatched to a lane).
    fn release(&mut self, n: usize) {
        self.queue.release(n);
    }
}

/// Per-lane accounting, identical across drivers.
#[derive(Default)]
struct LaneStats {
    busy_ns: u64,
    batches: u64,
    requests: u64,
    edge_pixels: u64,
    last_complete_ns: u64,
    latency: LatencyStats,
    queue_wait: LatencyStats,
    /// Completed requests per kind name.
    kinds: BTreeMap<&'static str, u64>,
    /// Executed pipeline phases per stage-span name (execution only).
    stage_runs: BTreeMap<&'static str, u64>,
}

impl LaneStats {
    /// Record one dispatched batch completing at `complete_ns`.
    fn record_batch(&mut self, batch: &FormedBatch, dispatch_ns: u64, complete_ns: u64) {
        self.busy_ns += complete_ns - dispatch_ns;
        self.batches += 1;
        self.last_complete_ns = self.last_complete_ns.max(complete_ns);
        for req in &batch.requests {
            self.requests += 1;
            *self.kinds.entry(req.kind.name()).or_insert(0) += 1;
            self.queue_wait.record(dispatch_ns.saturating_sub(req.arrival_ns));
            self.latency.record(complete_ns.saturating_sub(req.arrival_ns));
        }
    }

    /// Tally executed stage spans, mirroring them into the live
    /// telemetry registry when one is attached. `measured` gates the
    /// wall/cpu columns: wall drivers publish the measured spans,
    /// virtual replays publish runs only (zero time — measured
    /// durations would break byte-identical replay, the same rule the
    /// end-of-run report follows).
    fn note_stage_runs(
        &mut self,
        records: &[crate::canny::StageRecord],
        tel: Option<&Telemetry>,
        measured: bool,
    ) {
        for r in records {
            *self.stage_runs.entry(r.span_name()).or_insert(0) += 1;
            if let Some(t) = tel {
                let (wall, cpu) = if measured { (r.wall_ns, r.cpu_ns) } else { (0, 0) };
                t.note_stage(r.span_name(), wall, cpu);
            }
        }
    }

    /// Run the front over `img` and return its suppressed-magnitude
    /// map, recording the executed stages.
    fn run_front(
        &mut self,
        det: &Detector,
        img: &ImageF32,
        tel: Option<&Telemetry>,
        measured: bool,
        stages: &mut Vec<(String, u64)>,
    ) -> Result<ImageF32> {
        let plan = det.plan().stop_after(StageKind::Nms);
        let mut out = det.run_plan(&plan, Some(img), det.params())?;
        self.note_stage_runs(&out.records, tel, measured);
        push_stages(stages, &out.records, measured);
        out.take_suppressed()
            .ok_or_else(|| Error::Scheduler("front-only plan yielded no suppressed map".into()))
    }

    /// Run the real pipeline over the batch per its request kind
    /// (no records without a detector). Partial kinds go through the
    /// shared artifact cache under content-addressed keys; `opts`
    /// supplies the calibrated recompute estimate the admission policy
    /// weighs. Returns one [`ExecRecord`] per request, batch order —
    /// the trace evidence [`record_batch_spans`] turns into spans.
    fn execute_batch(
        &mut self,
        det: Option<&Detector>,
        cache: &ArtifactCache,
        opts: &ServeOptions,
        batch: &FormedBatch,
        tel: Option<&Telemetry>,
        measured: bool,
    ) -> Result<Vec<ExecRecord>> {
        let Some(det) = det else {
            return Ok(Vec::new());
        };
        let mut recs = Vec::with_capacity(batch.requests.len());
        for req in &batch.requests {
            let mut stages = Vec::new();
            let consult = match req.kind {
                RequestKind::Full => {
                    let img = generate(req.scene, req.width, req.height);
                    let out = det.detect_full(&img, det.params())?;
                    self.note_stage_runs(&out.records, tel, measured);
                    push_stages(&mut stages, &out.records, measured);
                    self.edge_pixels += out.edges.count_edges() as u64;
                    None
                }
                RequestKind::FrontOnly => {
                    let img = generate(req.scene, req.width, req.height);
                    let nm = self.run_front(det, &img, tel, measured, &mut stages)?;
                    if cache.enabled() {
                        offer_front(cache, opts, &img, nm);
                        Some("offer")
                    } else {
                        Some("disabled")
                    }
                }
                RequestKind::ReThreshold { lo, hi } => {
                    let params = CannyParams { lo, hi, ..*det.params() };
                    // Content addressing needs the content: generate
                    // the scene, hash it, then consult the shared tier.
                    let img = generate(req.scene, req.width, req.height);
                    let (cached, outcome) = if cache.enabled() {
                        let key = ArtifactKey::suppressed(&img);
                        match cache.consult(&key, CacheTier::Serve) {
                            (Some(Artifact::Suppressed(nm)), out) => (Some(nm), out),
                            // Key spans pin the artifact kind; anything
                            // else recomputes defensively.
                            (_, out) => (None, out),
                        }
                    } else {
                        (None, "disabled")
                    };
                    let nm = match cached {
                        Some(nm) => nm,
                        None => {
                            // Miss: compute the front once, offer it,
                            // then resume — the next re-threshold of
                            // this content hits, on any lane.
                            let nm = self.run_front(det, &img, tel, measured, &mut stages)?;
                            if cache.enabled() {
                                offer_front(cache, opts, &img, nm.clone());
                            }
                            nm
                        }
                    };
                    let plan = det.plan().from_suppressed(nm);
                    let out = det.run_plan(&plan, None, &params)?;
                    self.note_stage_runs(&out.records, tel, measured);
                    push_stages(&mut stages, &out.records, measured);
                    let edges = out.edges().ok_or_else(|| {
                        Error::Scheduler("re-threshold plan yielded no edges".into())
                    })?;
                    self.edge_pixels += edges.count_edges() as u64;
                    Some(outcome)
                }
            };
            recs.push(ExecRecord { cache: consult, stages });
        }
        Ok(recs)
    }
}

/// One request's execution evidence for tracing: the cache-consult
/// outcome (`None` for kinds that never probe the tier) and the
/// executed stage spans with measured durations (zeros under virtual
/// drivers, which model durations instead).
#[derive(Debug, Default)]
struct ExecRecord {
    cache: Option<&'static str>,
    stages: Vec<(String, u64)>,
}

/// Append `(span name, duration)` entries for freshly executed stage
/// `records`; durations are kept only when `measured` (wall drivers) —
/// virtual traces model them from the service span instead.
fn push_stages(
    stages: &mut Vec<(String, u64)>,
    records: &[crate::canny::StageRecord],
    measured: bool,
) {
    for r in records {
        stages.push((r.span_name().to_string(), if measured { r.wall_ns } else { 0 }));
    }
}

/// Record the span tree of every request in one completed batch into
/// the run's trace sink (no-op when `--trace-log` is off). Wall
/// drivers with real execution pass `measured = true` and keep the
/// stage walls; otherwise stage durations are modeled as an even split
/// of the service span minus the cache consult, so virtual replays
/// trace byte-identically.
///
/// This is also where the tail-sampling verdict lands: the request is
/// complete, so its end-to-end latency is known, and
/// [`ServeOptions::sampler`] decides whether the span tree is kept.
/// Kept requests additionally pin their trace id + latency as the
/// exemplar of the latency histogram bucket they land in — dropped
/// ones never do, so every exemplar a snapshot exports resolves to a
/// retained trace.
#[allow(clippy::too_many_arguments)]
fn record_batch_spans(
    opts: &ServeOptions,
    telemetry: &Telemetry,
    lane: usize,
    batch: &FormedBatch,
    dispatch_ns: u64,
    complete_ns: u64,
    recs: &[ExecRecord],
    measured: bool,
) {
    let Some(trace) = &opts.trace else {
        return;
    };
    for (i, req) in batch.requests.iter().enumerate() {
        let latency_ns = complete_ns.saturating_sub(req.arrival_ns);
        if !opts.sampler.keep(latency_ns, req.id) {
            continue;
        }
        let digest = content_digest(&req.scene.spec(), req.width, req.height);
        let id = TraceId::derive(digest, req.id);
        telemetry.latency.note_exemplar(latency_ns, id.as_str());
        let rec = recs.get(i);
        let cache = match rec.map(|r| r.cache) {
            Some(Some(outcome)) => Some((outcome, opts.cache_lookup_ns(req.pixels()))),
            Some(None) => None,
            // Execute-off runs model the consult the real path would
            // have done (the virtual clock charges it either way).
            None if req.kind.uses_artifact_cache() => {
                Some(("modeled", opts.cache_lookup_ns(req.pixels())))
            }
            None => None,
        };
        let executed = rec.filter(|r| !r.stages.is_empty());
        let stages: Vec<(String, u64)> = match executed {
            Some(r) if measured => r.stages.clone(),
            other => {
                let names: Vec<&str> = match other {
                    Some(r) => r.stages.iter().map(|(n, _)| n.as_str()).collect(),
                    None => kind_stage_names(batch.kind).to_vec(),
                };
                let span = complete_ns
                    .saturating_sub(dispatch_ns)
                    .saturating_sub(cache.map_or(0, |(_, d)| d));
                let durs = modeled_stage_durs(span, names.len());
                names.iter().map(|n| n.to_string()).zip(durs).collect()
            }
        };
        trace.record_all(request_spans(
            &id,
            lane as u64 + 1,
            req.arrival_ns,
            batch.formed_ns,
            dispatch_ns,
            complete_ns,
            cache,
            &stages,
        ));
    }
}

/// One arrival through the fault manager and the intake, with the
/// telemetry that goes with it — the one admission path both drivers
/// share, so a shed decision is counted identically under either
/// clock. Returns whatever batch the admission closed.
fn admit_one(
    intake: &mut Intake,
    fault: &FaultManager,
    slo_missed: bool,
    telemetry: &Telemetry,
    mut req: Request,
    now_ns: u64,
) -> Option<FormedBatch> {
    telemetry.offered.inc();
    match fault.decide(slo_missed, matches!(req.kind, RequestKind::Full)) {
        ShedDecision::Reject => {
            intake.queue.reject_shed();
            telemetry.rejected.inc();
            telemetry.shed_rejected.inc();
            return None;
        }
        ShedDecision::Degrade => {
            // The client still gets an answer — the cache-warming
            // front-only form at a fraction of the cost.
            req.kind = RequestKind::FrontOnly;
            telemetry.shed_degraded.inc();
        }
        ShedDecision::Admit => {}
    }
    let admitted_before = intake.queue.admitted;
    let formed = intake.arrive(req, now_ns);
    if intake.queue.admitted > admitted_before {
        telemetry.admitted.inc();
    } else {
        telemetry.rejected.inc();
    }
    telemetry.queue_depth.set(intake.queue.occupancy() as u64);
    telemetry.queue_high_water.raise(intake.queue.high_water as u64);
    formed
}

/// Driver-level totals the lanes cannot see (arrival accounting, the
/// end-of-run cache snapshot and the ops plane's final state).
struct RunTotals {
    offered: u64,
    interrupted: bool,
    cache: CacheSnapshot,
    /// Arrivals completed in degraded (front-only) form by the
    /// overload policy.
    shed_degraded: u64,
    /// The rolling SLO window's end state (quantiles, status,
    /// transition timeline).
    slo_window: WindowReport,
}

/// Roll driver results into the report (identical schema either way).
fn build_report(
    label: &str,
    opts: &ServeOptions,
    plan: (Engine, usize),
    totals: RunTotals,
    intake: &Intake,
    lanes: Vec<LaneStats>,
) -> ServeReport {
    let mut total_latency = LatencyStats::new();
    let mut queue_wait = LatencyStats::new();
    let mut completed = 0u64;
    let mut makespan_ns = 0u64;
    let mut edge_pixels = 0u64;
    let mut kinds: BTreeMap<String, u64> = BTreeMap::new();
    let mut stage_runs: BTreeMap<String, u64> = BTreeMap::new();
    for l in &lanes {
        total_latency.merge(&l.latency);
        queue_wait.merge(&l.queue_wait);
        completed += l.requests;
        makespan_ns = makespan_ns.max(l.last_complete_ns);
        edge_pixels += l.edge_pixels;
        for (&k, &v) in &l.kinds {
            *kinds.entry(k.to_string()).or_insert(0) += v;
        }
        for (&k, &v) in &l.stage_runs {
            *stage_runs.entry(k.to_string()).or_insert(0) += v;
        }
    }
    let lane_reports = lanes
        .iter()
        .enumerate()
        .map(|(i, l)| LaneReport {
            lane: i,
            requests: l.requests,
            batches: l.batches,
            busy_ns: l.busy_ns,
            latency: l.latency.summary(),
        })
        .collect();
    ServeReport {
        label: label.to_string(),
        seed: opts.seed,
        clock: opts.clock.name().to_string(),
        engine: plan.0.name().to_string(),
        workers_per_lane: plan.1,
        interrupted: totals.interrupted,
        offered: totals.offered,
        admitted: intake.queue.admitted,
        rejected_full: intake.queue.rejected_full,
        rejected_oversize: intake.queue.rejected_oversize,
        rejected_shed: intake.queue.rejected_shed,
        shed_degraded: totals.shed_degraded,
        overload_policy: opts.overload_policy.name().to_string(),
        completed,
        queue_depth: intake.queue.depth(),
        queue_high_water: intake.queue.high_water,
        batch_window_ns: opts.batch_window_ns,
        max_batch: opts.max_batch,
        batches_formed: intake.batcher.batches_formed,
        requests_batched: intake.batcher.requests_batched,
        makespan_ns,
        edge_pixels,
        latency: total_latency.summary(),
        queue_wait: queue_wait.summary(),
        lanes: lane_reports,
        slo_target_p99_ns: opts.slo_p99_ns,
        slo_window: totals.slo_window,
        cost_model: opts.cost_model(),
        kinds,
        stage_runs,
        cache: totals.cache,
    }
}

/// Serve `trace` under the clock selected in `opts` and return the SLO
/// report.
pub fn serve(label: &str, trace: &Trace, opts: &ServeOptions) -> Result<ServeReport> {
    match opts.clock {
        ClockMode::Virtual => serve_virtual(label, trace, opts),
        ClockMode::Wall => serve_wall(label, trace, opts),
    }
}

// ---- Virtual driver -----------------------------------------------------

/// Deterministic replay in modeled time.
///
/// Event loop invariants (all in virtual time):
/// * at one instant, lane completions free lanes first, then expired
///   batch windows close, then arrivals are admitted, then dispatch —
///   a lane freed at `t` can take a batch formed at `t`;
/// * dispatch is FIFO over closed batches onto the lowest-numbered
///   idle lane.
///
/// The ops plane rides the same event loop: modeled completions are
/// queued on a min-heap and folded into the telemetry registry and the
/// rolling SLO window in `(complete_ns, lane)` order, interleaved with
/// snapshot ticks at their grid times (completions first at an equal
/// instant). Every quantity on a telemetry line is modeled, so two
/// replays of the same trace write byte-identical JSONL — the
/// determinism contract extends from the report to the live stream.
fn serve_virtual(label: &str, trace: &Trace, opts: &ServeOptions) -> Result<ServeReport> {
    let (engine, workers_per_lane, params) = plan_lanes(trace, opts);
    struct VirtualLane {
        det: Option<Detector>,
        busy_until_ns: u64,
        stats: LaneStats,
    }
    /// One modeled batch completion, ordered by time then lane (the
    /// heap key) so equal-time completions fold deterministically.
    #[derive(PartialEq, Eq, PartialOrd, Ord)]
    struct Completion {
        complete_ns: u64,
        lane: usize,
        latencies: Vec<u64>,
    }
    /// Fold every completion and snapshot tick due at or before
    /// `up_to_ns` into the registry/window/log, in time order.
    fn drain_obs(
        up_to_ns: u64,
        completions: &mut BinaryHeap<Reverse<Completion>>,
        snap: &mut SnapshotEngine,
        window: &mut SloWindow,
        telemetry: &Telemetry,
        cache: &ArtifactCache,
        shedding_possible: bool,
    ) -> Result<()> {
        loop {
            let next_completion =
                completions.peek().map(|Reverse(c)| c.complete_ns).unwrap_or(u64::MAX);
            let next_tick = snap.next_tick_ns();
            if next_completion > up_to_ns && next_tick > up_to_ns {
                return Ok(());
            }
            if next_completion <= next_tick {
                let Reverse(c) = completions.pop().expect("peeked non-empty");
                let n = c.latencies.len() as u64;
                let lane = telemetry.lane(c.lane);
                lane.completed.add(n);
                lane.inflight.sub(n);
                lane.heartbeat_ns.raise(c.complete_ns);
                telemetry.completed.add(n);
                for &lat in &c.latencies {
                    telemetry.latency.record(lat);
                    window.record(c.complete_ns, lat);
                }
            } else if let Some(t) = snap.take_tick(up_to_ns) {
                snap.emit(TickInputs {
                    t_ns: t,
                    telemetry,
                    cache: cache.snapshot(),
                    slo: window.to_json(),
                    slo_missed: window.missed(),
                    shedding_possible,
                    utilization: None,
                })?;
            }
        }
    }

    // One shared tier across every lane; the single-threaded replay
    // touches it in a deterministic order, so the report's `cache`
    // section is as replayable as the latencies.
    let cache = build_cache(opts);
    let mut lanes: Vec<VirtualLane> = Vec::with_capacity(opts.lanes);
    for _ in 0..opts.lanes {
        lanes.push(VirtualLane {
            det: build_lane_detector(engine, workers_per_lane, params, opts.execute)?,
            busy_until_ns: 0,
            stats: LaneStats::default(),
        });
    }

    let telemetry = Telemetry::new("serve", opts.lanes);
    let mut window = SloWindow::new(opts.slo_p99_ns, opts.slo_window);
    let fault = FaultManager::new(opts.overload_policy);
    let mut snap = SnapshotEngine::from_options(
        opts.telemetry_log.as_deref(),
        opts.telemetry_interval_ns,
        opts.overload_policy.name(),
    )?
    .with_alerts(HealthTracker::from_spec(&opts.alert_log)?)
    .with_anomaly(AnomalyMonitor::from_sigma(opts.anomaly_sigma))
    .with_endpoint(opts.obs_endpoint.clone());
    let mut completions: BinaryHeap<Reverse<Completion>> = BinaryHeap::new();

    let mut intake = Intake::new(opts);
    let mut ready: VecDeque<FormedBatch> = VecDeque::new();
    let mut next = 0usize; // arrival cursor into trace.requests
    let mut now = 0u64;

    loop {
        // Dispatch everything possible at `now`: FIFO batches onto the
        // lowest-numbered idle lane.
        while !ready.is_empty() {
            let Some(idx) = lanes.iter().position(|l| l.busy_until_ns <= now) else {
                break;
            };
            let batch = ready.pop_front().expect("checked non-empty");
            let service_ns = opts.service_ns_batch(batch.kind, batch.pixels(), batch.len());
            let complete_ns = now + service_ns;
            intake.release(batch.len());
            telemetry.queue_depth.set(intake.queue.occupancy() as u64);
            let tl = telemetry.lane(idx);
            tl.batches.inc();
            tl.inflight.add(batch.len() as u64);
            tl.busy_ns.add(service_ns);
            tl.heartbeat_ns.raise(now);
            completions.push(Reverse(Completion {
                complete_ns,
                lane: idx,
                latencies: batch
                    .requests
                    .iter()
                    .map(|r| complete_ns.saturating_sub(r.arrival_ns))
                    .collect(),
            }));
            let lane = &mut lanes[idx];
            lane.busy_until_ns = complete_ns;
            lane.stats.record_batch(&batch, now, complete_ns);
            let recs = lane.stats.execute_batch(
                lane.det.as_ref(),
                &cache,
                opts,
                &batch,
                Some(&telemetry),
                false,
            )?;
            record_batch_spans(opts, &telemetry, idx, &batch, now, complete_ns, &recs, false);
        }

        // Next event: arrival, batch-window deadline, or (if work is
        // waiting to dispatch) the earliest lane-free time.
        let mut t = u64::MAX;
        if next < trace.requests.len() {
            t = t.min(trace.requests[next].arrival_ns);
        }
        if let Some(d) = intake.next_deadline() {
            t = t.min(d);
        }
        if !ready.is_empty() {
            if let Some(free) =
                lanes.iter().map(|l| l.busy_until_ns).filter(|&b| b > now).min()
            {
                t = t.min(free);
            }
        }
        if t == u64::MAX {
            break;
        }
        now = now.max(t);

        // Completions (and any telemetry ticks) up to `now` land before
        // new arrivals are judged — the fault manager sees the same
        // window state a wall driver's lanes would have published.
        drain_obs(
            now,
            &mut completions,
            &mut snap,
            &mut window,
            &telemetry,
            &cache,
            fault.active(),
        )?;

        for b in intake.expire(now) {
            ready.push_back(b);
        }
        while next < trace.requests.len() && trace.requests[next].arrival_ns <= now {
            let req = trace.requests[next];
            next += 1;
            if let Some(b) =
                admit_one(&mut intake, &fault, window.missed(), &telemetry, req, req.arrival_ns)
            {
                ready.push_back(b);
            }
        }
    }
    debug_assert_eq!(intake.batcher.pending(), 0);
    debug_assert_eq!(intake.queue.occupancy(), 0);

    // Fold the in-flight tail, then stamp the end state (the last line
    // of the log always shows the completed run). `now` can be past the
    // last completion when a tail of arrivals was shed without ever
    // occupying a lane — the end stamp is the later of the two, so
    // `t_ns` stays monotonic across the file.
    let end_ns = now.max(lanes.iter().map(|l| l.busy_until_ns).max().unwrap_or(0));
    drain_obs(
        end_ns,
        &mut completions,
        &mut snap,
        &mut window,
        &telemetry,
        &cache,
        fault.active(),
    )?;
    debug_assert!(completions.is_empty());
    if snap.enabled() || snap.alerts_active() || snap.endpoint_active() || snap.anomaly_active() {
        snap.emit(TickInputs {
            t_ns: end_ns,
            telemetry: &telemetry,
            cache: cache.snapshot(),
            slo: window.to_json(),
            slo_missed: window.missed(),
            shedding_possible: fault.active(),
            utilization: None,
        })?;
    }
    snap.close()?;
    if let Some(trace) = &opts.trace {
        trace.write()?;
    }

    let stats = lanes.into_iter().map(|l| l.stats).collect();
    let totals = RunTotals {
        offered: trace.len() as u64,
        interrupted: false,
        cache: cache.snapshot(),
        shed_degraded: telemetry.shed_degraded.get(),
        slo_window: window.report(),
    };
    Ok(build_report(label, opts, (engine, workers_per_lane), totals, &intake, stats))
}

// ---- Wall driver --------------------------------------------------------

/// Shared state between the wall driver's arrival thread and its lane
/// threads. `intake` is the same core the virtual driver uses, behind a
/// lock because lanes release occupancy concurrently with admissions.
struct WallShared {
    intake: Mutex<Intake>,
    dispatch: Mutex<WallDispatch>,
    cv: Condvar,
}

struct WallDispatch {
    ready: VecDeque<FormedBatch>,
    /// No further batches will arrive (arrival replay finished).
    closed: bool,
}

#[allow(clippy::too_many_arguments)]
fn wall_lane(
    lane_id: usize,
    det: Option<Detector>,
    opts: &ServeOptions,
    shared: &WallShared,
    cache: &ArtifactCache,
    clock: WallClock,
    telemetry: &Telemetry,
    window: &Mutex<SloWindow>,
) -> Result<LaneStats> {
    let mut stats = LaneStats::default();
    loop {
        let batch = {
            let mut d = shared.dispatch.lock().expect("dispatch lock");
            loop {
                if let Some(b) = d.ready.pop_front() {
                    break Some(b);
                }
                if d.closed {
                    break None;
                }
                d = shared.cv.wait(d).expect("dispatch wait");
            }
        };
        let Some(batch) = batch else {
            return Ok(stats);
        };
        {
            let mut intake = shared.intake.lock().expect("intake lock");
            intake.release(batch.len());
            telemetry.queue_depth.set(intake.queue.occupancy() as u64);
        }
        let n = batch.len() as u64;
        let tl = telemetry.lane(lane_id);
        let dispatch_ns = clock.now_ns();
        tl.batches.inc();
        tl.inflight.add(n);
        tl.heartbeat_ns.raise(dispatch_ns);
        let recs = if opts.execute {
            stats.execute_batch(det.as_ref(), cache, opts, &batch, Some(telemetry), true)?
        } else {
            // Scheduling-only runs still occupy the lane for the
            // modeled service time so wall studies work without
            // compute.
            std::thread::sleep(Duration::from_nanos(
                opts.service_ns_batch(batch.kind, batch.pixels(), batch.len()),
            ));
            Vec::new()
        };
        let complete_ns = clock.now_ns();
        stats.record_batch(&batch, dispatch_ns, complete_ns);
        record_batch_spans(
            opts,
            telemetry,
            lane_id,
            &batch,
            dispatch_ns,
            complete_ns,
            &recs,
            opts.execute,
        );
        tl.busy_ns.add(complete_ns.saturating_sub(dispatch_ns));
        tl.completed.add(n);
        tl.inflight.sub(n);
        tl.heartbeat_ns.raise(complete_ns);
        telemetry.completed.add(n);
        let mut w = window.lock().expect("slo window lock");
        for req in &batch.requests {
            let lat = complete_ns.saturating_sub(req.arrival_ns);
            telemetry.latency.record(lat);
            w.record(complete_ns, lat);
        }
    }
}

/// Real-time replay: arrivals paced to their trace offsets, lanes as
/// actual worker threads draining a shared dispatch channel. When
/// [`ServeOptions::interrupt`] is raised mid-replay the remaining
/// arrivals are abandoned, open batch windows are flushed so admitted
/// requests still complete, and the report is marked interrupted.
fn serve_wall(label: &str, trace: &Trace, opts: &ServeOptions) -> Result<ServeReport> {
    let (engine, workers_per_lane, params) = plan_lanes(trace, opts);
    // Build detectors before starting the clock so pool/planner setup
    // cost never pollutes the measured latencies.
    let mut dets: Vec<Option<Detector>> = Vec::with_capacity(opts.lanes);
    for _ in 0..opts.lanes {
        dets.push(build_lane_detector(engine, workers_per_lane, params, opts.execute)?);
    }
    // Per-lane pool handles for the telemetry sampler's utilization
    // section (empty when `execute` is off — nothing computes).
    let pools: Vec<PoolStats> = dets.iter().flatten().map(|d| d.pool_stats()).collect();

    let shared = Arc::new(WallShared {
        intake: Mutex::new(Intake::new(opts)),
        dispatch: Mutex::new(WallDispatch { ready: VecDeque::new(), closed: false }),
        cv: Condvar::new(),
    });
    // One shared tier drained by every lane thread — this is where the
    // sharded locking earns its keep (real cross-lane contention).
    let cache = build_cache(opts);
    let telemetry = Arc::new(Telemetry::new("serve", opts.lanes));
    let window = Arc::new(Mutex::new(SloWindow::new(opts.slo_p99_ns, opts.slo_window)));
    let fault = FaultManager::new(opts.overload_policy);
    let snap = SnapshotEngine::from_options(
        opts.telemetry_log.as_deref(),
        opts.telemetry_interval_ns,
        opts.overload_policy.name(),
    )?
    .with_alerts(HealthTracker::from_spec(&opts.alert_log)?)
    .with_anomaly(AnomalyMonitor::from_sigma(opts.anomaly_sigma))
    .with_endpoint(opts.obs_endpoint.clone());
    let clock = WallClock::start();
    let snapshotter = {
        let telemetry = Arc::clone(&telemetry);
        let cache = Arc::clone(&cache);
        let window = Arc::clone(&window);
        WallSnapshotter::start(
            snap,
            telemetry,
            pools,
            Box::new(move || clock.now_ns()),
            Box::new(move || cache.snapshot()),
            Box::new(move || {
                let w = window.lock().expect("slo window lock");
                (w.to_json(), w.missed())
            }),
            fault.active(),
        )
    };
    let mut handles = Vec::with_capacity(opts.lanes);
    for (lane_id, det) in dets.into_iter().enumerate() {
        let shared = Arc::clone(&shared);
        let cache = Arc::clone(&cache);
        let telemetry = Arc::clone(&telemetry);
        let window = Arc::clone(&window);
        let opts = opts.clone();
        handles.push(std::thread::spawn(move || {
            wall_lane(lane_id, det, &opts, &shared, &cache, clock, &telemetry, &window)
        }));
    }

    // Arrival replay on this thread: sleep to the next event (arrival
    // or batch-window deadline), then run the same expire-then-admit
    // step the virtual driver runs.
    let mut next = 0usize;
    let mut interrupted = false;
    loop {
        if opts.interrupted() {
            interrupted = true;
            break;
        }
        let deadline = shared.intake.lock().expect("intake lock").next_deadline();
        let mut t = u64::MAX;
        if next < trace.requests.len() {
            t = t.min(trace.requests[next].arrival_ns);
        }
        if let Some(d) = deadline {
            t = t.min(d);
        }
        if t == u64::MAX {
            break;
        }
        if opts.interrupt.is_none() {
            clock.sleep_until(t);
        } else {
            // Sleep in short slices so a raised interrupt flag is
            // noticed promptly even far from the next event.
            loop {
                if opts.interrupted() {
                    interrupted = true;
                    break;
                }
                let now = clock.now_ns();
                if now >= t {
                    break;
                }
                std::thread::sleep(Duration::from_nanos((t - now).min(INTERRUPT_POLL_NS)));
            }
            if interrupted {
                break;
            }
        }
        let now = clock.now_ns();
        // Read the rolling SLO status before taking the intake lock
        // (lanes take the window lock on completion; never nested with
        // the intake lock on either side).
        let slo_missed = window.lock().expect("slo window lock").missed();
        let mut formed = Vec::new();
        {
            let mut intake = shared.intake.lock().expect("intake lock");
            formed.extend(intake.expire(now));
            while next < trace.requests.len() && trace.requests[next].arrival_ns <= now {
                let req = trace.requests[next];
                next += 1;
                // Window deadlines run on the wall clock (`now`), so a
                // late-woken arrival can never create an already-expired
                // group.
                if let Some(b) = admit_one(&mut intake, &fault, slo_missed, &telemetry, req, now)
                {
                    formed.push(b);
                }
            }
        }
        if !formed.is_empty() {
            let mut d = shared.dispatch.lock().expect("dispatch lock");
            for b in formed {
                d.ready.push_back(b);
                shared.cv.notify_one();
            }
        }
    }
    if interrupted {
        // Drain: close every open batch window so admitted requests
        // complete instead of vanishing with the replay.
        let flushed = {
            let mut intake = shared.intake.lock().expect("intake lock");
            intake.batcher.flush(clock.now_ns())
        };
        if !flushed.is_empty() {
            let mut d = shared.dispatch.lock().expect("dispatch lock");
            for b in flushed {
                d.ready.push_back(b);
                shared.cv.notify_one();
            }
        }
    }
    {
        let mut d = shared.dispatch.lock().expect("dispatch lock");
        d.closed = true;
        shared.cv.notify_all();
    }

    let mut stats = Vec::with_capacity(handles.len());
    let mut first_err: Option<Error> = None;
    for h in handles {
        match h.join() {
            Ok(Ok(s)) => stats.push(s),
            Ok(Err(e)) => {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
            Err(_) => {
                if first_err.is_none() {
                    first_err = Some(Error::Scheduler("serve lane panicked".into()));
                }
            }
        }
    }
    // Lanes have quiesced: stop the telemetry sampler (it emits one
    // final line first, so the log always ends on the drained state).
    let (snap, _usage) = snapshotter.finish(label)?;
    snap.close()?;
    if let Some(e) = first_err {
        return Err(e);
    }
    if let Some(trace) = &opts.trace {
        trace.write()?;
    }
    // Take the window report before the intake lock: never hold two
    // serve-side mutexes at once (the lock-discipline lint enforces it).
    let slo_window = window.lock().expect("slo window lock").report();
    let intake = shared.intake.lock().expect("intake lock");
    debug_assert_eq!(intake.batcher.pending(), 0);
    debug_assert_eq!(intake.queue.occupancy(), 0);
    // `offered` counts arrivals that reached an admission decision —
    // equal to the trace length unless the replay was interrupted.
    let totals = RunTotals {
        offered: next as u64,
        interrupted,
        cache: cache.snapshot(),
        shed_degraded: telemetry.shed_degraded.get(),
        slo_window,
    };
    Ok(build_report(label, opts, (engine, workers_per_lane), totals, &intake, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth::Scene;
    use crate::service::slo::SloStatus;

    fn opts() -> ServeOptions {
        let mut o = ServeOptions::from_config(&RunConfig::default());
        o.execute = false;
        o
    }

    #[test]
    fn conservation_offered_equals_completed_plus_rejected() {
        let trace = Trace::synthetic(120, 11, 5_000.0);
        let report = serve("t", &trace, &opts()).unwrap();
        assert_eq!(report.offered, 120);
        assert_eq!(report.offered, report.completed + report.rejected());
        assert_eq!(report.admitted, report.completed);
        assert!(report.makespan_ns > 0);
        assert!(report.batches_formed > 0);
        assert!(report.queue_high_water >= 1);
        assert_eq!(report.clock, "virtual");
        assert!(!report.interrupted);
        assert_eq!(report.kinds.get("full"), Some(&report.completed));
    }

    #[test]
    fn lanes_share_the_load() {
        let mut o = opts();
        o.lanes = 3;
        // Arrival pressure high enough that one lane cannot keep up.
        let trace = Trace::synthetic(300, 5, 50_000.0);
        let report = serve("t", &trace, &o).unwrap();
        assert_eq!(report.lanes.len(), 3);
        let active = report.lanes.iter().filter(|l| l.requests > 0).count();
        assert!(active >= 2, "only {active} lanes took work");
        assert_eq!(
            report.lanes.iter().map(|l| l.requests).sum::<u64>(),
            report.completed
        );
    }

    #[test]
    fn tiny_queue_rejects_under_burst() {
        let mut o = opts();
        o.queue_depth = 2;
        o.lanes = 1;
        // Very high rate: arrivals bunch faster than one lane drains.
        let trace = Trace::synthetic(100, 3, 1_000_000.0);
        let report = serve("t", &trace, &o).unwrap();
        assert!(report.rejected_full > 0, "expected backpressure rejections");
        assert!(report.queue_high_water <= 2);
    }

    #[test]
    fn empty_trace_is_a_noop_report() {
        let report = serve("t", &Trace::default(), &opts()).unwrap();
        assert_eq!(report.offered, 0);
        assert_eq!(report.makespan_ns, 0);
        assert_eq!(report.throughput_rps(), 0.0);
        // Zero completions is *not* an SLO pass (satellite bugfix).
        assert_eq!(report.slo_status(), SloStatus::NoData);
        assert!(!report.slo_met());
    }

    #[test]
    fn wider_window_forms_fewer_batches() {
        let base = Trace::synthetic(200, 9, 20_000.0);
        let mut narrow = opts();
        narrow.batch_window_ns = 0;
        let mut wide = opts();
        wide.batch_window_ns = 10_000_000; // 10 ms
        let rn = serve("narrow", &base, &narrow).unwrap();
        let rw = serve("wide", &base, &wide).unwrap();
        assert!(
            rw.batches_formed < rn.batches_formed,
            "wide {} vs narrow {}",
            rw.batches_formed,
            rn.batches_formed
        );
        assert!(rw.mean_batch_fill() > rn.mean_batch_fill());
    }

    #[test]
    fn calibration_replaces_the_synthetic_constants() {
        let mut o = opts();
        o.lanes = 1;
        o.max_batch = 1;
        o.batch_window_ns = 0;
        o.calibration = Some(Calibration {
            engine: "patterns".into(),
            workers: 1,
            overhead_ns: 7_000,
            cost_ns_per_pixel: 2.0,
            stages: Vec::new(),
            probes: Vec::new(),
        });
        assert_eq!(o.service_ns(1_000), 9_000);
        // One 32x32 request at t=0, immediate window: latency is exactly
        // the calibrated cost.
        let trace = Trace {
            requests: vec![Request {
                id: 0,
                arrival_ns: 0,
                scene: Scene::Gradient,
                width: 32,
                height: 32,
                kind: RequestKind::Full,
            }],
        };
        let report = serve("calib", &trace, &o).unwrap();
        assert_eq!(report.latency.max_ns, 7_000 + 2 * 32 * 32);
        let j = report.to_json();
        assert_eq!(
            j.get("calibration").unwrap().get("source").unwrap().as_str(),
            Some("measured")
        );
    }

    #[test]
    fn kind_costs_scale_with_their_stage_sets() {
        let o = opts();
        // Large enough that the per-pixel terms dominate the fixed
        // cache-lookup overhead (kind ordering is a per-pixel claim).
        let px = 100_000usize;
        let full = o.service_ns_kind(RequestKind::Full, px);
        let front = o.service_ns_kind(RequestKind::FrontOnly, px);
        let re = o.service_ns_kind(RequestKind::ReThreshold { lo: 0.1, hi: 0.2 }, px);
        assert!(re < front && front < full, "re {re} front {front} full {full}");
        assert_eq!(full, o.service_ns(px));

        // Per-stage calibration beats the synthetic fractions; cache
        // kinds additionally pay the modeled lookup (hash + probe).
        let mut c = opts();
        c.calibration = Some(Calibration {
            engine: "patterns".into(),
            workers: 1,
            overhead_ns: 10_000,
            cost_ns_per_pixel: 4.0,
            stages: ["pad", "gaussian", "sobel", "nms", "threshold", "hysteresis"]
                .iter()
                .map(|s| crate::service::calibrate::StageCost {
                    stage: s.to_string(),
                    overhead_ns: 1_000,
                    cost_ns_per_pixel: 0.5,
                })
                .collect(),
            probes: Vec::new(),
        });
        let lookup = c.cache_lookup_ns(px);
        assert_eq!(lookup, CACHE_LOOKUP_OVERHEAD_NS + px as u64 / 2);
        assert_eq!(
            c.service_ns_kind(RequestKind::FrontOnly, px),
            4 * (1_000 + px as u64 / 2) + lookup
        );
        assert_eq!(
            c.service_ns_kind(RequestKind::ReThreshold { lo: 0.1, hi: 0.2 }, px),
            2 * (1_000 + px as u64 / 2) + lookup
        );
        // A disabled tier charges no lookup — the real path skips the
        // hash too.
        let mut off = opts();
        off.cache = CacheConfig::disabled();
        assert_eq!(off.cache_lookup_ns(px), 0);
        assert_eq!(
            off.service_ns_kind(RequestKind::FrontOnly, px),
            o.service_ns_kind(RequestKind::FrontOnly, px) - lookup
        );
    }

    #[test]
    fn batch_costs_charge_the_probe_per_request() {
        let o = opts();
        let (px, n) = (10_000usize, 4usize);
        let re = RequestKind::ReThreshold { lo: 0.1, hi: 0.2 };
        // Each of the n requests hashes and probes the tier.
        assert_eq!(
            o.service_ns_batch(re, px, n),
            o.service_ns_kind(re, px) + (n as u64 - 1) * CACHE_LOOKUP_OVERHEAD_NS
        );
        assert_eq!(o.service_ns_batch(re, px, 1), o.service_ns_kind(re, px));
        // Full batches never touch the cache; neither does a disabled
        // tier.
        assert_eq!(
            o.service_ns_batch(RequestKind::Full, px, n),
            o.service_ns_kind(RequestKind::Full, px)
        );
        let mut off = opts();
        off.cache = CacheConfig::disabled();
        assert_eq!(off.service_ns_batch(re, px, n), off.service_ns_kind(re, px));
    }

    #[test]
    fn effective_cache_follows_the_injected_handle() {
        let mut o = opts();
        o.cache = CacheConfig::disabled();
        assert!(!o.cache_enabled());
        assert_eq!(o.cache_lookup_ns(100), 0);
        // An injected enabled tier wins over a disabled run config…
        o.shared_cache = Some(Arc::new(ArtifactCache::new(CacheConfig::default())));
        assert!(o.cache_enabled());
        assert!(o.cache_lookup_ns(100) > 0);
        // …and an injected disabled tier wins over an enabled one, so
        // the modeled lookup charge always matches the executed path.
        o.cache = CacheConfig::default();
        o.shared_cache = Some(Arc::new(ArtifactCache::disabled()));
        assert!(!o.cache_enabled());
        assert_eq!(o.cache_lookup_ns(100), 0);
    }

    #[test]
    fn wall_clock_smoke_run_matches_schema() {
        let mut o = opts();
        o.clock = ClockMode::Wall;
        o.lanes = 2;
        // Tiny modeled costs keep the sleep-based lanes fast.
        o.batch_overhead_ns = 10_000;
        o.cost_ns_per_pixel = 0;
        // 30 requests at 100 kHz -> ~300 µs of replay.
        let trace = Trace::synthetic(30, 3, 100_000.0);
        let report = serve("wall", &trace, &o).unwrap();
        assert_eq!(report.clock, "wall");
        assert_eq!(report.offered, 30);
        assert_eq!(report.offered, report.completed + report.rejected());
        assert!(report.makespan_ns > 0);
        assert!(!report.interrupted);
        // Same JSON schema as the virtual report.
        let virt = serve("virt", &trace, &opts()).unwrap();
        let (a, b) = (report.to_json(), virt.to_json());
        let keys = |j: &crate::util::json::Json| -> Vec<String> {
            j.as_obj().unwrap().keys().cloned().collect()
        };
        assert_eq!(keys(&a), keys(&b));
    }
}
