//! Run reports: the measured quantities every bench/figure consumes,
//! plus conversion of stage timings into simulator specs.

use crate::canny::StageTimes;
use crate::metrics::coefficient_of_variation;
use crate::scheduler::PoolStats;
use crate::simsched::{SimPhase, SimSpec};
use crate::util::timer::human_ns;

/// Summary of one detection (or batch) run.
#[derive(Clone, Debug, Default)]
pub struct RunReport {
    pub label: String,
    pub pixels: usize,
    pub wall_ns: u64,
    pub times: StageTimes,
    /// Per-worker busy ns (from PoolStats), when a pool was used.
    pub worker_busy_ns: Vec<u64>,
    pub tasks: u64,
    pub steals: u64,
}

impl RunReport {
    pub fn from_run(
        label: &str,
        pixels: usize,
        times: &StageTimes,
        stats: Option<&PoolStats>,
    ) -> RunReport {
        let (worker_busy_ns, tasks, steals) = match stats {
            Some(s) => (s.busy_ns_per_worker(), s.total_tasks(), s.total_steals()),
            None => (Vec::new(), 0, 0),
        };
        RunReport {
            label: label.to_string(),
            pixels,
            wall_ns: times.total_ns,
            times: times.clone(),
            worker_busy_ns,
            tasks,
            steals,
        }
    }

    /// Throughput in megapixels per second.
    pub fn mpix_per_s(&self) -> f64 {
        self.pixels as f64 / 1e6 / (self.wall_ns as f64 / 1e9).max(1e-12)
    }

    /// Load balance (CoV of per-worker busy time; 0 = perfectly even —
    /// the Figure 3 metric).
    pub fn load_cov(&self) -> f64 {
        coefficient_of_variation(
            &self.worker_busy_ns.iter().map(|&n| n as f64).collect::<Vec<_>>(),
        )
    }

    /// Build the simulator spec from this run's measured stage costs:
    /// pad + hysteresis serial (the paper's 1-f), tile costs parallel.
    /// Falls back to per-stage serial phases when no tile costs exist.
    pub fn to_sim_spec(&self) -> SimSpec {
        let t = &self.times;
        let mut phases = Vec::new();
        if t.pad_ns > 0 {
            phases.push(SimPhase::serial("pad", t.pad_ns));
        }
        if !t.tile_costs_ns.is_empty() {
            phases.push(SimPhase::parallel("front", t.tile_costs_ns.clone()));
        } else {
            for (label, ns) in [
                ("gaussian", t.gaussian_ns),
                ("sobel", t.sobel_ns),
                ("nms", t.nms_ns),
                ("threshold", t.threshold_ns),
            ] {
                if ns > 0 {
                    phases.push(SimPhase::serial(label, ns));
                }
            }
        }
        if t.hysteresis_ns > 0 {
            phases.push(SimPhase::serial("hysteresis", t.hysteresis_ns));
        }
        SimSpec { phases }
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{}: {} ({:.2} Mpix/s), front {}, hysteresis {}, {} tasks, {} steals, load CoV {:.3}",
            self.label,
            human_ns(self.wall_ns),
            self.mpix_per_s(),
            human_ns(self.times.front_ns),
            human_ns(self.times.hysteresis_ns),
            self.tasks,
            self.steals,
            self.load_cov(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn times() -> StageTimes {
        StageTimes {
            pad_ns: 10,
            front_ns: 400,
            hysteresis_ns: 90,
            total_ns: 500,
            tile_costs_ns: vec![100, 100, 100, 100],
            ..Default::default()
        }
    }

    #[test]
    fn throughput_math() {
        let r = RunReport {
            pixels: 1_000_000,
            wall_ns: 500_000_000, // 0.5 s
            ..Default::default()
        };
        assert!((r.mpix_per_s() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn sim_spec_from_tiled_run() {
        let r = RunReport { times: times(), ..Default::default() };
        let spec = r.to_sim_spec();
        assert_eq!(spec.phases.len(), 3); // pad, front, hysteresis
        assert_eq!(spec.phases[1].tasks_ns.len(), 4);
        assert_eq!(spec.work_ns(), 10 + 400 + 90);
    }

    #[test]
    fn sim_spec_from_serial_run() {
        let t = StageTimes {
            pad_ns: 5,
            gaussian_ns: 50,
            sobel_ns: 30,
            nms_ns: 20,
            threshold_ns: 10,
            hysteresis_ns: 40,
            total_ns: 160,
            ..Default::default()
        };
        let r = RunReport { times: t, ..Default::default() };
        let spec = r.to_sim_spec();
        assert_eq!(spec.phases.len(), 6);
        assert!((spec.serial_fraction() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn summary_contains_key_fields() {
        let r = RunReport {
            label: "x".into(),
            pixels: 100,
            wall_ns: 1000,
            times: times(),
            worker_busy_ns: vec![10, 12],
            tasks: 4,
            steals: 1,
        };
        let s = r.summary();
        assert!(s.contains("x:"));
        assert!(s.contains("steals"));
    }
}
