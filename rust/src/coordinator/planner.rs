//! The GCP *kernel* layer: given a problem description and a topology,
//! choose the execution plan — engine, worker count, tile geometry,
//! band grain. Heuristics are deliberately simple and documented; the
//! ablation bench validates the tile-size choice empirically.

use crate::canny::{CannyParams, Engine};
use crate::coordinator::topology::CpuTopology;

/// What the shell hands the planner.
#[derive(Clone, Copy, Debug)]
pub struct Workload {
    pub image_w: usize,
    pub image_h: usize,
    /// Images per job (batch size); 1 for single-shot.
    pub batch: usize,
}

impl Workload {
    pub fn pixels(&self) -> usize {
        self.image_w * self.image_h
    }
}

/// The chosen plan.
#[derive(Clone, Debug)]
pub struct Plan {
    pub engine: Engine,
    pub workers: usize,
    pub params: CannyParams,
    /// Human-readable rationale (surfaces in `cannyd info`).
    pub rationale: String,
}

/// GCP kernel-layer planner.
#[derive(Clone, Debug)]
pub struct Planner {
    pub topology: CpuTopology,
    /// Whether XLA artifacts are available.
    pub xla_available: bool,
}

impl Planner {
    pub fn new(topology: CpuTopology) -> Planner {
        Planner { topology, xla_available: false }
    }

    pub fn with_xla(mut self, available: bool) -> Planner {
        self.xla_available = available;
        self
    }

    /// Produce a plan for `work` starting from `base` parameters.
    pub fn plan(&self, work: Workload, base: &CannyParams) -> Plan {
        let workers = self.topology.recommended_workers();
        let mut params = *base;
        let mut why = Vec::new();

        // Tiny images: parallel overhead dominates below ~16k pixels/task.
        let engine = if work.pixels() < 32 * 32 || workers == 1 {
            why.push("image too small / 1 CPU -> serial".to_string());
            Engine::Serial
        } else if work.batch > workers {
            // A deep batch saturates the pool at image granularity; the
            // per-image engine can stay serial inside farm workers... but
            // tile-level parallelism composes via nested scopes, so keep
            // the fused-tile engine (best locality).
            why.push(format!("batch {} > workers {} -> tiled farm", work.batch, workers));
            Engine::TiledPatterns
        } else if self.xla_available {
            why.push("artifacts present -> PJRT fused front".to_string());
            Engine::PatternsXla
        } else {
            why.push("stage-parallel patterns".to_string());
            Engine::Patterns
        };

        // Tile size: aim for >= 4 tiles per worker but tiles no smaller
        // than 64 (front cost amortizes halo overhead ~ (c+8)^2/c^2).
        let target_tiles = workers * 4;
        let mut tile = params.tile.max(32);
        while tile > 64
            && (work.image_w.div_ceil(tile) * work.image_h.div_ceil(tile)) < target_tiles
        {
            tile /= 2;
        }
        if tile != params.tile {
            why.push(format!("tile {} -> {} for >= {} tasks", params.tile, tile, target_tiles));
            params.tile = tile;
        }

        // Band grain: ~4 bands per worker over the image height.
        params.band_grain = (work.image_h / (workers * 4)).max(1);

        Plan { engine, workers, params, rationale: why.join("; ") }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn planner(cpus: usize) -> Planner {
        Planner::new(CpuTopology::manycore(cpus))
    }

    #[test]
    fn tiny_image_goes_serial() {
        let p = planner(8).plan(
            Workload { image_w: 16, image_h: 16, batch: 1 },
            &CannyParams::default(),
        );
        assert_eq!(p.engine, Engine::Serial);
    }

    #[test]
    fn single_cpu_goes_serial() {
        let p = Planner::new(CpuTopology::manycore(1)).plan(
            Workload { image_w: 1024, image_h: 1024, batch: 1 },
            &CannyParams::default(),
        );
        assert_eq!(p.engine, Engine::Serial);
    }

    #[test]
    fn xla_preferred_when_available() {
        let p = planner(8).with_xla(true).plan(
            Workload { image_w: 1024, image_h: 1024, batch: 1 },
            &CannyParams::default(),
        );
        assert_eq!(p.engine, Engine::PatternsXla);
    }

    #[test]
    fn deep_batch_uses_tiled_farm() {
        let p = planner(4).plan(
            Workload { image_w: 512, image_h: 512, batch: 64 },
            &CannyParams::default(),
        );
        assert_eq!(p.engine, Engine::TiledPatterns);
    }

    #[test]
    fn tile_shrinks_for_small_images_many_workers() {
        let p = planner(8).plan(
            Workload { image_w: 256, image_h: 256, batch: 1 },
            &CannyParams::default(),
        );
        assert!(p.params.tile <= 64, "tile={}", p.params.tile);
        // 256/64 = 4 -> 16 tiles < 32 target but floor at 64.
    }

    #[test]
    fn big_image_keeps_big_tiles() {
        let p = planner(4).plan(
            Workload { image_w: 4096, image_h: 4096, batch: 1 },
            &CannyParams::default(),
        );
        assert_eq!(p.params.tile, 128);
        assert!(p.params.band_grain >= 1);
    }

    #[test]
    fn rationale_is_populated() {
        let p = planner(8).plan(
            Workload { image_w: 64, image_h: 64, batch: 1 },
            &CannyParams::default(),
        );
        assert!(!p.rationale.is_empty());
    }
}
