//! Batch server: the farm-pattern front door for bulk IFE workloads
//! (directories of images / streams of frames), with bounded
//! backpressure — the paper's motivating scenario of "large quantities
//! of images … on the INTERNET".

use crate::canny::CannyParams;
use crate::coordinator::Detector;
use crate::error::Result;
use crate::image::{EdgeMap, ImageF32};
use crate::patterns::farm::{farm_stream, FarmStats};
use crate::util::timer::Stopwatch;

/// One batch job.
#[derive(Clone, Debug)]
pub struct BatchJob {
    pub id: usize,
    pub image: ImageF32,
}

/// Result of a batch run.
#[derive(Debug)]
pub struct BatchReport {
    pub results: Vec<EdgeMap>,
    pub wall_ns: u64,
    pub pixels: usize,
    pub farm: FarmStats,
}

impl BatchReport {
    pub fn mpix_per_s(&self) -> f64 {
        self.pixels as f64 / 1e6 / (self.wall_ns as f64 / 1e9).max(1e-12)
    }

    pub fn images_per_s(&self) -> f64 {
        self.results.len() as f64 / (self.wall_ns as f64 / 1e9).max(1e-12)
    }
}

/// Farm-based batch executor over a detector's resources.
#[derive(Debug)]
pub struct BatchServer<'a> {
    detector: &'a Detector,
    /// Max images in flight (queue bound / backpressure).
    pub capacity: usize,
}

impl<'a> BatchServer<'a> {
    pub fn new(detector: &'a Detector) -> BatchServer<'a> {
        BatchServer { detector, capacity: detector.n_workers() * 2 }
    }

    pub fn with_capacity(mut self, capacity: usize) -> Self {
        self.capacity = capacity.max(1);
        self
    }

    /// Process a stream of jobs; results come back in submission order.
    ///
    /// Each image is detected with the *serial* per-image pipeline —
    /// at batch depth, image-level parallelism already saturates the
    /// pool, and nesting tile scopes inside farm tasks only adds
    /// scheduling overhead (ablated in `ablation_patterns`).
    pub fn run(
        &self,
        jobs: impl IntoIterator<Item = BatchJob>,
        params: &CannyParams,
    ) -> Result<BatchReport> {
        params.validate()?;
        let sw = Stopwatch::start();
        let pixel_count = std::sync::atomic::AtomicUsize::new(0);
        let (results, farm) = farm_stream(
            self.detector.pool(),
            jobs,
            self.capacity,
            |_idx, job: BatchJob| {
                pixel_count.fetch_add(job.image.len(), std::sync::atomic::Ordering::Relaxed);
                let (cls, _) = crate::canny::front_serial(&job.image, params.lo, params.hi);
                crate::canny::hysteresis::hysteresis_serial(&cls)
            },
        );
        Ok(BatchReport {
            results,
            wall_ns: sw.elapsed_ns(),
            pixels: pixel_count.into_inner(),
            farm,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth::{generate, Scene};

    #[test]
    fn batch_results_match_single_runs() {
        let det = Detector::builder().workers(4).build().unwrap();
        let params = CannyParams::default();
        let images: Vec<ImageF32> =
            (0..6).map(|k| generate(Scene::Shapes { seed: k }, 80, 60)).collect();
        let jobs = images
            .iter()
            .cloned()
            .enumerate()
            .map(|(id, image)| BatchJob { id, image });
        let report = BatchServer::new(&det).run(jobs, &params).unwrap();
        assert_eq!(report.results.len(), 6);
        assert_eq!(report.pixels, 6 * 80 * 60);
        for (k, img) in images.iter().enumerate() {
            let single = crate::canny::CannyPipeline::serial()
                .detect(img, &params)
                .unwrap();
            assert_eq!(report.results[k].diff_count(&single.edges), 0, "image {k}");
        }
    }

    #[test]
    fn backpressure_capacity_respected() {
        let det = Detector::builder().workers(2).build().unwrap();
        let jobs = (0..20).map(|k| BatchJob {
            id: k,
            image: generate(Scene::Checker { cell: 4 }, 40, 40),
        });
        let report = BatchServer::new(&det)
            .with_capacity(2)
            .run(jobs, &CannyParams::default())
            .unwrap();
        assert_eq!(report.results.len(), 20);
        assert!(report.farm.stalls > 0, "tight capacity should stall the feeder");
    }
}
