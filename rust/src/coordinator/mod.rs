//! The GCP (Golden Circle of Parallelism) coordinator — the paper's
//! structural model realized as code:
//!
//! * **Shell** ([`Detector::builder`] + [`batch::BatchJob`]): turns the
//!   real-world problem ("edges in these images") into a parallel plan.
//! * **Kernel** ([`planner`]): optimizes the plan for the concrete
//!   parallel architecture — worker count from the topology, engine,
//!   tile geometry, grain.
//! * **Core** ([`Detector::detect`] / [`batch::BatchServer`]): executes
//!   on the work-stealing pool (and PJRT engine), collecting the run
//!   reports the figures are built from.

pub mod batch;
pub mod planner;
pub mod report;
pub mod topology;

pub use batch::{BatchReport, BatchServer};
pub use planner::{Plan, Planner};
pub use report::RunReport;
pub use topology::CpuTopology;

use std::sync::Arc;

use crate::canny::plan::{PlanOutput, StagePlan};
use crate::canny::{CannyParams, CannyPipeline, DetectOutput, Engine};
use crate::config::RunConfig;
use crate::error::{Error, Result};
use crate::image::{EdgeMap, ImageF32};
use crate::runtime::{Manifest, XlaEngine};
use crate::scheduler::{Pool, PoolStats};

/// The end-user entry point: owns the pool (and XLA engine when
/// configured) and runs detections through the configured engine.
#[derive(Debug)]
pub struct Detector {
    engine: Engine,
    pool: Arc<Pool>,
    xla: Option<Arc<XlaEngine>>,
    params: CannyParams,
}

impl Detector {
    /// Start building a detector.
    pub fn builder() -> DetectorBuilder {
        DetectorBuilder::default()
    }

    /// Build straight from a [`RunConfig`] (the CLI path).
    pub fn from_config(cfg: &RunConfig) -> Result<Detector> {
        cfg.validate()?;
        let mut b = Detector::builder()
            .engine(cfg.engine)
            .workers(cfg.workers)
            .params(cfg.params);
        if cfg.engine == Engine::PatternsXla {
            b = b.artifacts_dir(&cfg.artifacts_dir);
            if !cfg.tile_name.is_empty() {
                b = b.tile_name(&cfg.tile_name);
            }
            if cfg.xla_replicas > 0 {
                b = b.xla_replicas(cfg.xla_replicas);
            }
        }
        b.build()
    }

    /// Detect edges; returns only the edge map.
    pub fn detect(&self, img: &ImageF32, params: &CannyParams) -> Result<EdgeMap> {
        Ok(self.detect_full(img, params)?.edges)
    }

    /// Detect with class map, magnitude and stage timings.
    pub fn detect_full(&self, img: &ImageF32, params: &CannyParams) -> Result<DetectOutput> {
        self.pipeline().detect(img, params)
    }

    /// Detect with the detector's own default parameters.
    pub fn detect_default(&self, img: &ImageF32) -> Result<EdgeMap> {
        self.detect(img, &self.params)
    }

    /// Start a [`StagePlan`] over the stage graph: pick a stop stage
    /// (front-only, gradient-only, …), an entry artifact (re-threshold
    /// a cached suppressed-magnitude map) and per-stage overrides, then
    /// run it with [`Detector::run_plan`].
    pub fn plan(&self) -> StagePlan {
        StagePlan::new()
    }

    /// Execute a [`StagePlan`] on this detector's resources. `img` is
    /// required iff the plan starts from a raw image.
    pub fn run_plan(
        &self,
        plan: &StagePlan,
        img: Option<&ImageF32>,
        params: &CannyParams,
    ) -> Result<PlanOutput> {
        self.pipeline().execute(plan, img, params)
    }

    /// The configured default parameters.
    pub fn params(&self) -> &CannyParams {
        &self.params
    }

    pub fn engine(&self) -> Engine {
        self.engine
    }

    pub fn n_workers(&self) -> usize {
        self.pool.n_workers()
    }

    /// Live stats (for the profiler).
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }

    /// Borrow the pool (patterns / farm use).
    pub fn pool(&self) -> &Pool {
        &self.pool
    }

    /// The pipeline view over this detector's resources.
    pub fn pipeline(&self) -> CannyPipeline<'_> {
        CannyPipeline { engine: self.engine, pool: Some(&self.pool), xla: self.xla.as_deref() }
    }
}

/// Builder for [`Detector`].
#[derive(Debug, Default)]
pub struct DetectorBuilder {
    engine: Option<Engine>,
    workers: usize,
    params: Option<CannyParams>,
    artifacts_dir: Option<String>,
    tile_name: Option<String>,
    xla_replicas: usize,
}

impl DetectorBuilder {
    pub fn engine(mut self, engine: Engine) -> Self {
        self.engine = Some(engine);
        self
    }

    /// 0 = auto (from host topology).
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    pub fn params(mut self, params: CannyParams) -> Self {
        self.params = Some(params);
        self
    }

    pub fn artifacts_dir(mut self, dir: &str) -> Self {
        self.artifacts_dir = Some(dir.to_string());
        self
    }

    pub fn tile_name(mut self, name: &str) -> Self {
        self.tile_name = Some(name.to_string());
        self
    }

    pub fn xla_replicas(mut self, n: usize) -> Self {
        self.xla_replicas = n;
        self
    }

    pub fn build(self) -> Result<Detector> {
        let engine = self.engine.unwrap_or(Engine::Patterns);
        let params = self.params.unwrap_or_default();
        params.validate()?;
        let topo = CpuTopology::detect();
        let workers = if self.workers > 0 { self.workers } else { topo.recommended_workers() };
        let pool = Arc::new(Pool::new(workers)?);
        let xla = if engine == Engine::PatternsXla {
            let dir = self
                .artifacts_dir
                .unwrap_or_else(|| Manifest::default_dir().to_string_lossy().into_owned());
            let manifest = Manifest::load(std::path::Path::new(&dir))?;
            let tile_name = match self.tile_name {
                Some(n) => n,
                None => manifest.closest_tile(params.tile).name.clone(),
            };
            let replicas =
                if self.xla_replicas > 0 { self.xla_replicas } else { workers.min(8) };
            Some(Arc::new(XlaEngine::from_manifest(&manifest, &tile_name, replicas)?))
        } else {
            None
        };
        if engine == Engine::PatternsXla && xla.is_none() {
            return Err(Error::Xla("xla engine failed to initialize".into()));
        }
        Ok(Detector { engine, pool, xla, params })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth::{generate, Scene};

    #[test]
    fn builder_defaults() {
        let det = Detector::builder().workers(2).build().unwrap();
        assert_eq!(det.engine(), Engine::Patterns);
        assert_eq!(det.n_workers(), 2);
    }

    #[test]
    fn detect_roundtrip() {
        let det = Detector::builder().workers(2).build().unwrap();
        let img = generate(Scene::Checker { cell: 8 }, 64, 64);
        let edges = det.detect_default(&img).unwrap();
        assert!(edges.count_edges() > 0);
    }

    #[test]
    fn from_config_serial() {
        let mut cfg = RunConfig::default();
        cfg.set("engine", "serial").unwrap();
        cfg.set("workers", "1").unwrap();
        let det = Detector::from_config(&cfg).unwrap();
        assert_eq!(det.engine(), Engine::Serial);
    }

    #[test]
    fn plan_roundtrip_through_detector() {
        use crate::canny::StageKind;
        let det = Detector::builder().workers(2).build().unwrap();
        let img = generate(Scene::Checker { cell: 8 }, 48, 48);
        let front = det.plan().stop_after(StageKind::Nms);
        let mut out = det.run_plan(&front, Some(&img), det.params()).unwrap();
        let nm = out.take_suppressed().unwrap();
        let re = det.plan().from_suppressed(nm);
        let out2 = det.run_plan(&re, None, det.params()).unwrap();
        let full = det.detect_default(&img).unwrap();
        assert_eq!(full.diff_count(out2.edges().unwrap()), 0);
    }

    #[test]
    fn invalid_params_rejected() {
        let r = Detector::builder()
            .workers(1)
            .params(CannyParams { lo: 0.9, hi: 0.1, ..CannyParams::default() })
            .build();
        assert!(r.is_err());
    }
}
