//! CPU topology discovery + the paper's Table-1 simulated testbeds.

/// A description of the (real or simulated) processor the coordinator
/// plans for.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CpuTopology {
    /// Human-readable name.
    pub name: String,
    /// Logical CPUs visible to the scheduler.
    pub logical_cpus: usize,
    /// Physical cores (= logical/2 when hyperthreaded).
    pub physical_cores: usize,
    /// Whether this topology is simulated (virtual-time figures) or the
    /// live host.
    pub simulated: bool,
}

impl CpuTopology {
    /// The live host topology (affinity-aware).
    pub fn detect() -> CpuTopology {
        let logical = available_cpus();
        CpuTopology {
            name: format!("host ({logical} logical CPUs)"),
            logical_cpus: logical,
            physical_cores: logical.div_ceil(2).max(1),
            simulated: false,
        }
    }

    /// Paper Table 1, row 1: Intel Core i3 — 2 cores, 4 CPUs, 3.4 GHz.
    pub fn i3_4cpu() -> CpuTopology {
        CpuTopology {
            name: "Core i3 (2 cores, 4 CPUs) [simulated]".into(),
            logical_cpus: 4,
            physical_cores: 2,
            simulated: true,
        }
    }

    /// Paper Table 1, row 2: Intel Core i7 — 4 cores, 8 CPUs, 3.4 GHz.
    pub fn i7_8cpu() -> CpuTopology {
        CpuTopology {
            name: "Core i7 (4 cores, 8 CPUs) [simulated]".into(),
            logical_cpus: 8,
            physical_cores: 4,
            simulated: true,
        }
    }

    /// The paper's future-work manycore probe (§4: "32-64 CPUs").
    pub fn manycore(cpus: usize) -> CpuTopology {
        CpuTopology {
            name: format!("manycore ({cpus} CPUs) [simulated]"),
            logical_cpus: cpus,
            physical_cores: cpus / 2,
            simulated: true,
        }
    }

    /// Worker count the planner should use on this topology.
    pub fn recommended_workers(&self) -> usize {
        self.logical_cpus.max(1)
    }

    /// The Table-1 sweep (plus the host) used by the benches.
    pub fn table1() -> Vec<CpuTopology> {
        vec![CpuTopology::i3_4cpu(), CpuTopology::i7_8cpu()]
    }
}

/// Logical CPUs available to this process (sched_getaffinity-aware,
/// falling back to available_parallelism).
pub fn available_cpus() -> usize {
    // SAFETY: zeroed cpu_set_t is a valid argument; sched_getaffinity
    // writes into it.
    unsafe {
        let mut set: libc::cpu_set_t = std::mem::zeroed();
        if libc::sched_getaffinity(0, std::mem::size_of::<libc::cpu_set_t>(), &mut set) == 0 {
            let n = libc::CPU_COUNT(&set) as usize;
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_topology_sane() {
        let t = CpuTopology::detect();
        assert!(t.logical_cpus >= 1);
        assert!(!t.simulated);
        assert!(t.recommended_workers() >= 1);
    }

    #[test]
    fn table1_matches_paper() {
        let t = CpuTopology::table1();
        assert_eq!(t.len(), 2);
        assert_eq!((t[0].physical_cores, t[0].logical_cpus), (2, 4));
        assert_eq!((t[1].physical_cores, t[1].logical_cpus), (4, 8));
        assert!(t.iter().all(|x| x.simulated));
    }

    #[test]
    fn manycore_probe() {
        let t = CpuTopology::manycore(64);
        assert_eq!(t.logical_cpus, 64);
    }
}
