//! Tiling: the decomposition the GCP *kernel* layer feeds to the
//! parallel patterns. A [`TileGrid`] splits an image interior into
//! core tiles; each tile knows how to extract its haloed window from a
//! padded image and where its output lands in the full-size result.

use crate::error::{Error, Result};
use crate::image::ImageF32;

/// One tile of the decomposition.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Tile {
    /// Tile index in the grid (row-major).
    pub index: usize,
    /// Top-left of the tile core in *image* coordinates.
    pub y0: usize,
    pub x0: usize,
    /// Core size (may be smaller at the right/bottom edges).
    pub core_h: usize,
    pub core_w: usize,
}

/// A grid decomposition of a `width x height` image into tiles of at
/// most `tile_h x tile_w` core pixels, each carrying a `halo` border.
#[derive(Clone, Debug)]
pub struct TileGrid {
    pub image_w: usize,
    pub image_h: usize,
    pub tile_w: usize,
    pub tile_h: usize,
    pub halo: usize,
    pub cols: usize,
    pub rows: usize,
}

impl TileGrid {
    pub fn new(
        image_w: usize,
        image_h: usize,
        tile_w: usize,
        tile_h: usize,
        halo: usize,
    ) -> Result<TileGrid> {
        if image_w == 0 || image_h == 0 {
            return Err(Error::Geometry("empty image".into()));
        }
        if tile_w == 0 || tile_h == 0 {
            return Err(Error::Geometry("empty tile".into()));
        }
        Ok(TileGrid {
            image_w,
            image_h,
            tile_w,
            tile_h,
            halo,
            cols: image_w.div_ceil(tile_w),
            rows: image_h.div_ceil(tile_h),
        })
    }

    /// Total number of tiles.
    pub fn len(&self) -> usize {
        self.rows * self.cols
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The `i`-th tile (row-major).
    pub fn tile(&self, i: usize) -> Tile {
        debug_assert!(i < self.len());
        let ty = i / self.cols;
        let tx = i % self.cols;
        let y0 = ty * self.tile_h;
        let x0 = tx * self.tile_w;
        Tile {
            index: i,
            y0,
            x0,
            core_h: (self.image_h - y0).min(self.tile_h),
            core_w: (self.image_w - x0).min(self.tile_w),
        }
    }

    /// Iterate all tiles.
    pub fn tiles(&self) -> impl Iterator<Item = Tile> + '_ {
        (0..self.len()).map(|i| self.tile(i))
    }

    /// Extract the haloed input window for `tile` from the `halo`-padded
    /// image (as produced by [`ImageF32::pad_replicate`]). The window is
    /// always `(core + 2*halo)` sized: edge tiles read replicated pixels.
    pub fn extract_padded(&self, padded: &ImageF32, tile: Tile) -> ImageF32 {
        debug_assert_eq!(padded.width(), self.image_w + 2 * self.halo);
        debug_assert_eq!(padded.height(), self.image_h + 2 * self.halo);
        // Tile core at (y0, x0) in image coords = (y0 + halo, x0 + halo)
        // in padded coords; the window starts halo earlier.
        padded.crop(tile.x0, tile.y0, tile.core_w + 2 * self.halo, tile.core_h + 2 * self.halo)
    }

    /// Extract the haloed window for `tile` directly from the
    /// *unpadded* image, replicating out-of-bounds pixels (clamp to
    /// edge). Semantically identical to `pad_replicate(halo)` +
    /// [`TileGrid::extract_padded`], but does the halo work inside the
    /// (parallel) tile task instead of a serial whole-image pad pass —
    /// see EXPERIMENTS.md §Perf.
    pub fn extract_clamped(&self, img: &ImageF32, tile: Tile) -> ImageF32 {
        debug_assert_eq!(img.width(), self.image_w);
        debug_assert_eq!(img.height(), self.image_h);
        let r = self.halo;
        let (w, h) = (self.image_w, self.image_h);
        let (ww, wh) = (tile.core_w + 2 * r, tile.core_h + 2 * r);
        let mut data = Vec::with_capacity(ww * wh);
        for wy in 0..wh {
            // Source row, clamped to the image.
            let sy = (tile.y0 + wy).saturating_sub(r).min(h - 1);
            let src = img.row(sy);
            // Columns [x0 - r, x0 - r + ww) clamped into [0, w).
            let x_lo = (tile.x0 + 0).saturating_sub(r); // left clamp target
            let left_pad = r.saturating_sub(tile.x0); // columns clamped to 0
            let copy_w = (ww - left_pad).min(w - x_lo);
            data.resize(data.len() + left_pad, src[0]);
            data.extend_from_slice(&src[x_lo..x_lo + copy_w]);
            let right_pad = ww - left_pad - copy_w;
            data.resize(data.len() + right_pad, src[w - 1]);
        }
        ImageF32::from_vec(ww, wh, data).expect("window sized")
    }

    /// Extract a window for a *fixed-size* executable: the window is
    /// `(full_core + 2*halo)` even when the tile core is clipped; the
    /// caller discards rows/cols beyond `core_h/core_w` after execution.
    /// Requires the padded image to have at least that much data, which
    /// holds when callers pad with `pad_for_fixed`.
    pub fn extract_fixed(&self, padded: &ImageF32, tile: Tile) -> ImageF32 {
        padded.crop(tile.x0, tile.y0, self.tile_w + 2 * self.halo, self.tile_h + 2 * self.halo)
    }

    /// Max absolute pixel difference between `a` and `b` over the
    /// haloed neighborhood of `tile` — the temporal change measure the
    /// stream tier's delta gate thresholds ([`crate::stream::DeltaGate`]).
    ///
    /// The compared region is the tile core dilated by `halo` and
    /// clipped to the image. Replicated out-of-bounds window pixels are
    /// copies of in-image pixels inside that region, so a zero here
    /// means the tile's *entire* clamped input window is identical —
    /// and therefore every front artifact over the tile core is too
    /// (the delta gate's exact-reuse guarantee).
    pub fn tile_delta(&self, a: &ImageF32, b: &ImageF32, tile: Tile) -> f32 {
        self.tile_delta_exceeds(a, b, tile, f32::INFINITY)
    }

    /// Like [`TileGrid::tile_delta`], but stops scanning (at a row
    /// boundary) once the difference exceeds `budget`: the returned
    /// running max is then already conclusive for a dirty verdict,
    /// while results within the budget are still exact — what the
    /// delta gate's drift accumulator needs.
    pub fn tile_delta_exceeds(&self, a: &ImageF32, b: &ImageF32, tile: Tile, budget: f32) -> f32 {
        debug_assert_eq!((a.width(), a.height()), (self.image_w, self.image_h));
        debug_assert_eq!((b.width(), b.height()), (self.image_w, self.image_h));
        let r = self.halo;
        let y0 = tile.y0.saturating_sub(r);
        let y1 = (tile.y0 + tile.core_h + r).min(self.image_h);
        let x0 = tile.x0.saturating_sub(r);
        let x1 = (tile.x0 + tile.core_w + r).min(self.image_w);
        let mut worst = 0.0f32;
        for y in y0..y1 {
            let ra = &a.row(y)[x0..x1];
            let rb = &b.row(y)[x0..x1];
            for (&va, &vb) in ra.iter().zip(rb) {
                worst = worst.max((va - vb).abs());
            }
            if worst > budget {
                return worst;
            }
        }
        worst
    }

    /// Pad an image so that every `extract_fixed` window is in bounds:
    /// replicate-pad by `halo`, then extend right/bottom to the grid.
    pub fn pad_for_fixed(&self, img: &ImageF32) -> ImageF32 {
        let need_w = self.cols * self.tile_w + 2 * self.halo;
        let need_h = self.rows * self.tile_h + 2 * self.halo;
        let base = img.pad_replicate(self.halo);
        if base.width() == need_w && base.height() == need_h {
            return base;
        }
        let mut out = ImageF32::zeros(need_w, need_h);
        for y in 0..need_h {
            let sy = y.min(base.height() - 1);
            for x in 0..need_w {
                let sx = x.min(base.width() - 1);
                out.set(y, x, base.get(sy, sx));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_covers_image_exactly() {
        let g = TileGrid::new(300, 200, 128, 128, 4).unwrap();
        assert_eq!(g.cols, 3);
        assert_eq!(g.rows, 2);
        let mut covered = vec![false; 300 * 200];
        for t in g.tiles() {
            for y in t.y0..t.y0 + t.core_h {
                for x in t.x0..t.x0 + t.core_w {
                    assert!(!covered[y * 300 + x], "tile overlap at {y},{x}");
                    covered[y * 300 + x] = true;
                }
            }
        }
        assert!(covered.iter().all(|&c| c), "coverage gap");
    }

    #[test]
    fn edge_tiles_clip() {
        let g = TileGrid::new(130, 130, 128, 128, 4).unwrap();
        let t = g.tile(3); // bottom-right
        assert_eq!((t.core_w, t.core_h), (2, 2));
    }

    #[test]
    fn extract_padded_matches_direct_window() {
        let img =
            ImageF32::from_vec(8, 8, (0..64).map(|v| v as f32).collect()).unwrap();
        let g = TileGrid::new(8, 8, 4, 4, 2).unwrap();
        let padded = img.pad_replicate(2);
        let t = g.tile(3); // core at (4,4)
        let win = g.extract_padded(&padded, t);
        assert_eq!(win.width(), 8);
        assert_eq!(win.height(), 8);
        // Centre of the window = original pixel at (4+1, 4+1)... window
        // (wy, wx) maps to image (t.y0 + wy - halo, ...) clamped.
        assert_eq!(win.get(2, 2), img.get(4, 4));
        assert_eq!(win.get(3, 4), img.get(5, 6));
    }

    #[test]
    fn fixed_windows_in_bounds() {
        let img = ImageF32::zeros(130, 70);
        let g = TileGrid::new(130, 70, 64, 64, 4).unwrap();
        let padded = g.pad_for_fixed(&img);
        assert_eq!(padded.width(), 3 * 64 + 8);
        assert_eq!(padded.height(), 2 * 64 + 8);
        for t in g.tiles() {
            let win = g.extract_fixed(&padded, t);
            assert_eq!(win.width(), 72);
            assert_eq!(win.height(), 72);
        }
    }

    #[test]
    fn extract_clamped_equals_pad_then_extract() {
        let mut rng = crate::util::Prng::new(5);
        for (w, h, tile, halo) in [(8usize, 8usize, 4usize, 2usize), (13, 9, 5, 4), (30, 22, 16, 4)] {
            let data: Vec<f32> = (0..w * h).map(|_| rng.next_f32()).collect();
            let img = ImageF32::from_vec(w, h, data).unwrap();
            let g = TileGrid::new(w, h, tile, tile, halo).unwrap();
            let padded = img.pad_replicate(halo);
            for t in g.tiles() {
                let a = g.extract_padded(&padded, t);
                let b = g.extract_clamped(&img, t);
                assert_eq!(a, b, "{w}x{h} tile {tile} halo {halo} idx {}", t.index);
            }
        }
    }

    #[test]
    fn rejects_degenerate() {
        assert!(TileGrid::new(0, 10, 4, 4, 1).is_err());
        assert!(TileGrid::new(10, 10, 0, 4, 1).is_err());
    }

    #[test]
    fn tile_delta_zero_for_identical_images() {
        let img = ImageF32::from_vec(16, 16, (0..256).map(|v| v as f32).collect()).unwrap();
        let g = TileGrid::new(16, 16, 8, 8, 2).unwrap();
        for t in g.tiles() {
            assert_eq!(g.tile_delta(&img, &img, t), 0.0);
        }
    }

    #[test]
    fn tile_delta_sees_halo_neighborhood() {
        // 16x16, 8px tiles, halo 2. A change at (8, 8) sits in tile 3's
        // core but inside the halo ring of every other tile too.
        let a = ImageF32::zeros(16, 16);
        let mut b = a.clone();
        b.set(8, 8, 0.5);
        let g = TileGrid::new(16, 16, 8, 8, 2).unwrap();
        for t in g.tiles() {
            assert_eq!(g.tile_delta(&a, &b, t), 0.5, "tile {}", t.index);
        }
        // A change outside a tile's haloed window leaves it clean: with
        // halo 2, (0, 0) is 6 rows/cols away from tile 3's window edge.
        let mut c = a.clone();
        c.set(0, 0, 1.0);
        assert_eq!(g.tile_delta(&a, &c, g.tile(3)), 0.0);
        assert_eq!(g.tile_delta(&a, &c, g.tile(0)), 1.0);
    }

    #[test]
    fn tile_delta_is_max_abs_diff() {
        let a = ImageF32::zeros(8, 8);
        let mut b = a.clone();
        b.set(2, 2, 0.25);
        b.set(5, 5, -0.75);
        let g = TileGrid::new(8, 8, 8, 8, 4).unwrap();
        assert_eq!(g.tile_delta(&a, &b, g.tile(0)), 0.75);
    }

    #[test]
    fn tile_delta_exceeds_is_exact_within_budget_and_conclusive_past_it() {
        let a = ImageF32::zeros(8, 8);
        let mut b = a.clone();
        b.set(1, 1, 0.3); // early row
        b.set(6, 6, 0.9); // later row
        let g = TileGrid::new(8, 8, 8, 8, 4).unwrap();
        let t = g.tile(0);
        // Within budget: exact max, full scan.
        assert_eq!(g.tile_delta_exceeds(&a, &b, t, 1.0), 0.9);
        // Past the budget: the early exit may miss the later 0.9, but
        // whatever it returns is already over the budget.
        assert!(g.tile_delta_exceeds(&a, &b, t, 0.2) > 0.2);
        // Exact-match budget 0 still returns 0 for identical images.
        assert_eq!(g.tile_delta_exceeds(&a, &a, t, 0.0), 0.0);
    }
}
