//! Image substrate: owned f32 grayscale images, PGM/PPM codec,
//! procedural scene generators (the paper's OpenCV test images,
//! substituted per DESIGN.md), padding and tiling.

pub mod pgm;
pub mod synth;
pub mod tile;

use crate::error::{Error, Result};

/// Row-major f32 grayscale image, values nominally in [0, 1].
#[derive(Clone, Debug, PartialEq)]
pub struct ImageF32 {
    width: usize,
    height: usize,
    data: Vec<f32>,
}

impl ImageF32 {
    /// Zero-filled image.
    pub fn zeros(width: usize, height: usize) -> ImageF32 {
        ImageF32 { width, height, data: vec![0.0; width * height] }
    }

    /// Build from raw row-major data.
    pub fn from_vec(width: usize, height: usize, data: Vec<f32>) -> Result<ImageF32> {
        if data.len() != width * height {
            return Err(Error::Geometry(format!(
                "data len {} != {}x{}",
                data.len(),
                width,
                height
            )));
        }
        Ok(ImageF32 { width, height, data })
    }

    pub fn width(&self) -> usize {
        self.width
    }

    pub fn height(&self) -> usize {
        self.height
    }

    /// Total pixels.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Immutable raw data (row-major).
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable raw data (row-major).
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Pixel accessor (debug-checked).
    #[inline]
    pub fn get(&self, y: usize, x: usize) -> f32 {
        debug_assert!(y < self.height && x < self.width);
        self.data[y * self.width + x]
    }

    /// Pixel setter (debug-checked).
    #[inline]
    pub fn set(&mut self, y: usize, x: usize, v: f32) {
        debug_assert!(y < self.height && x < self.width);
        self.data[y * self.width + x] = v;
    }

    /// A single row as a slice.
    #[inline]
    pub fn row(&self, y: usize) -> &[f32] {
        &self.data[y * self.width..(y + 1) * self.width]
    }

    /// Replicate-pad by `r` pixels on every side (clamp-to-edge), the
    /// halo policy every engine uses so tile borders match whole-image
    /// borders exactly. Row-level memcpy for the interior; only the
    /// 2r border columns are filled per-pixel (§Perf: this stage is on
    /// the serial path of every engine).
    pub fn pad_replicate(&self, r: usize) -> ImageF32 {
        let (w, h) = (self.width, self.height);
        let (pw, ph) = (w + 2 * r, h + 2 * r);
        // Build by appending rows: every output byte is touched exactly
        // once (no zero-fill prepass).
        let mut data = Vec::with_capacity(pw * ph);
        for py in 0..ph {
            let sy = py.saturating_sub(r).min(h - 1);
            let src = self.row(sy);
            data.resize(data.len() + r, src[0]);
            data.extend_from_slice(src);
            data.resize(data.len() + r, src[w - 1]);
        }
        ImageF32 { width: pw, height: ph, data }
    }

    /// Copy a rectangular window (debug-checked bounds).
    pub fn crop(&self, x0: usize, y0: usize, w: usize, h: usize) -> ImageF32 {
        debug_assert!(x0 + w <= self.width && y0 + h <= self.height);
        let mut data = Vec::with_capacity(w * h);
        for y in y0..y0 + h {
            data.extend_from_slice(&self.data[y * self.width + x0..y * self.width + x0 + w]);
        }
        ImageF32 { width: w, height: h, data }
    }

    /// Mean pixel value.
    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        (self.data.iter().map(|&v| v as f64).sum::<f64>() / self.data.len() as f64) as f32
    }

    /// Min/max pixel values.
    pub fn min_max(&self) -> (f32, f32) {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &v in &self.data {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        (lo, hi)
    }

    /// Convert to 8-bit by clamping to 0..=1 and scaling.
    pub fn to_u8(&self) -> ImageU8 {
        ImageU8 {
            width: self.width,
            height: self.height,
            data: self
                .data
                .iter()
                .map(|&v| (v.clamp(0.0, 1.0) * 255.0).round() as u8)
                .collect(),
        }
    }
}

/// Row-major u8 grayscale image (I/O form).
#[derive(Clone, Debug, PartialEq)]
pub struct ImageU8 {
    width: usize,
    height: usize,
    data: Vec<u8>,
}

impl ImageU8 {
    pub fn from_vec(width: usize, height: usize, data: Vec<u8>) -> Result<ImageU8> {
        if data.len() != width * height {
            return Err(Error::Geometry(format!(
                "data len {} != {}x{}",
                data.len(),
                width,
                height
            )));
        }
        Ok(ImageU8 { width, height, data })
    }

    pub fn width(&self) -> usize {
        self.width
    }

    pub fn height(&self) -> usize {
        self.height
    }

    pub fn data(&self) -> &[u8] {
        &self.data
    }

    /// Convert to f32 in [0, 1].
    pub fn to_f32(&self) -> ImageF32 {
        ImageF32 {
            width: self.width,
            height: self.height,
            data: self.data.iter().map(|&v| v as f32 / 255.0).collect(),
        }
    }
}

/// Edge map: the detector's output. 0 = background, 255 = edge.
#[derive(Clone, Debug, PartialEq)]
pub struct EdgeMap {
    width: usize,
    height: usize,
    data: Vec<u8>,
}

impl EdgeMap {
    pub fn new(width: usize, height: usize, data: Vec<u8>) -> Result<EdgeMap> {
        if data.len() != width * height {
            return Err(Error::Geometry("edge map size mismatch".into()));
        }
        Ok(EdgeMap { width, height, data })
    }

    pub fn width(&self) -> usize {
        self.width
    }

    pub fn height(&self) -> usize {
        self.height
    }

    pub fn data(&self) -> &[u8] {
        &self.data
    }

    #[inline]
    pub fn is_edge(&self, y: usize, x: usize) -> bool {
        self.data[y * self.width + x] != 0
    }

    /// Number of edge pixels.
    pub fn count_edges(&self) -> usize {
        self.data.iter().filter(|&&v| v != 0).count()
    }

    /// Fraction of pixels that are edges.
    pub fn edge_density(&self) -> f64 {
        self.count_edges() as f64 / self.data.len().max(1) as f64
    }

    /// As a u8 image (0/255) for writing to PGM.
    pub fn to_image(&self) -> ImageU8 {
        ImageU8 { width: self.width, height: self.height, data: self.data.clone() }
    }

    /// Count differing pixels vs another map (determinism checks).
    pub fn diff_count(&self, other: &EdgeMap) -> usize {
        assert_eq!((self.width, self.height), (other.width, other.height));
        self.data
            .iter()
            .zip(&other.data)
            .filter(|(a, b)| (**a != 0) != (**b != 0))
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_vec_validates() {
        assert!(ImageF32::from_vec(3, 3, vec![0.0; 8]).is_err());
        assert!(ImageF32::from_vec(3, 3, vec![0.0; 9]).is_ok());
    }

    #[test]
    fn get_set_roundtrip() {
        let mut img = ImageF32::zeros(5, 4);
        img.set(3, 2, 0.7);
        assert_eq!(img.get(3, 2), 0.7);
        assert_eq!(img.get(0, 0), 0.0);
    }

    #[test]
    fn pad_replicate_clamps_edges() {
        let img = ImageF32::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let p = img.pad_replicate(2);
        assert_eq!(p.width(), 6);
        assert_eq!(p.height(), 6);
        assert_eq!(p.get(0, 0), 1.0); // top-left corner replicated
        assert_eq!(p.get(0, 5), 2.0);
        assert_eq!(p.get(5, 0), 3.0);
        assert_eq!(p.get(5, 5), 4.0);
        assert_eq!(p.get(2, 2), 1.0); // interior preserved
        assert_eq!(p.get(3, 3), 4.0);
    }

    #[test]
    fn crop_extracts_window() {
        let img = ImageF32::from_vec(4, 3, (0..12).map(|v| v as f32).collect()).unwrap();
        let c = img.crop(1, 1, 2, 2);
        assert_eq!(c.data(), &[5.0, 6.0, 9.0, 10.0]);
    }

    #[test]
    fn u8_f32_roundtrip() {
        let img = ImageU8::from_vec(2, 1, vec![0, 255]).unwrap();
        let f = img.to_f32();
        assert_eq!(f.data(), &[0.0, 1.0]);
        assert_eq!(f.to_u8().data(), &[0, 255]);
    }

    #[test]
    fn edge_map_counts() {
        let em = EdgeMap::new(2, 2, vec![0, 255, 255, 0]).unwrap();
        assert_eq!(em.count_edges(), 2);
        assert!((em.edge_density() - 0.5).abs() < 1e-12);
        assert!(em.is_edge(0, 1));
        assert!(!em.is_edge(0, 0));
    }

    #[test]
    fn edge_map_diff() {
        let a = EdgeMap::new(2, 1, vec![0, 255]).unwrap();
        let b = EdgeMap::new(2, 1, vec![255, 255]).unwrap();
        assert_eq!(a.diff_count(&b), 1);
        assert_eq!(a.diff_count(&a), 0);
    }

    #[test]
    fn stats() {
        let img = ImageF32::from_vec(2, 2, vec![0.0, 0.5, 1.0, 0.5]).unwrap();
        assert!((img.mean() - 0.5).abs() < 1e-6);
        assert_eq!(img.min_max(), (0.0, 1.0));
    }
}
