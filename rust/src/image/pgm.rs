//! Binary PGM (P5) / PPM (P6) codec — the no-dependency substitute for
//! the paper's OpenCV image I/O. P5 is the native grayscale format;
//! P6 is read by luma conversion so RGB test assets also work.

use std::fs;
use std::io::Write as _;
use std::path::Path;

use crate::error::{Error, Result};
use crate::image::ImageU8;

/// Write an 8-bit grayscale image as binary PGM (P5).
pub fn write_pgm(path: &Path, img: &ImageU8) -> Result<()> {
    if let Some(parent) = path.parent() {
        fs::create_dir_all(parent)?;
    }
    let mut f = fs::File::create(path)?;
    write!(f, "P5\n{} {}\n255\n", img.width(), img.height())?;
    f.write_all(img.data())?;
    Ok(())
}

/// Read a binary PGM (P5) or PPM (P6, converted to luma) image.
pub fn read_pgm(path: &Path) -> Result<ImageU8> {
    let bytes = fs::read(path)?;
    decode(&bytes)
}

/// Decode from memory. Supports `P5` (maxval <= 255) and `P6`.
pub fn decode(bytes: &[u8]) -> Result<ImageU8> {
    let mut pos = 0usize;
    let magic = token(bytes, &mut pos)?;
    let channels = match magic.as_str() {
        "P5" => 1usize,
        "P6" => 3usize,
        other => return Err(Error::Codec(format!("unsupported magic `{other}`"))),
    };
    let width: usize = parse_num(&token(bytes, &mut pos)?)?;
    let height: usize = parse_num(&token(bytes, &mut pos)?)?;
    let maxval: usize = parse_num(&token(bytes, &mut pos)?)?;
    if maxval == 0 || maxval > 255 {
        return Err(Error::Codec(format!("unsupported maxval {maxval}")));
    }
    // Exactly one whitespace byte separates the header from raster data.
    pos += 1;
    let need = width * height * channels;
    if bytes.len() < pos + need {
        return Err(Error::Codec(format!(
            "truncated raster: need {need} bytes, have {}",
            bytes.len().saturating_sub(pos)
        )));
    }
    let raster = &bytes[pos..pos + need];
    let scale = 255.0 / maxval as f32;
    let data: Vec<u8> = if channels == 1 {
        raster.iter().map(|&v| ((v as f32) * scale).round() as u8).collect()
    } else {
        raster
            .chunks_exact(3)
            .map(|px| {
                // BT.601 luma, the standard grayscale conversion.
                let y = 0.299 * px[0] as f32 + 0.587 * px[1] as f32 + 0.114 * px[2] as f32;
                (y * scale).round().min(255.0) as u8
            })
            .collect()
    };
    ImageU8::from_vec(width, height, data)
}

/// Next whitespace-delimited header token, skipping `#` comments.
fn token(bytes: &[u8], pos: &mut usize) -> Result<String> {
    loop {
        while *pos < bytes.len() && bytes[*pos].is_ascii_whitespace() {
            *pos += 1;
        }
        if *pos < bytes.len() && bytes[*pos] == b'#' {
            while *pos < bytes.len() && bytes[*pos] != b'\n' {
                *pos += 1;
            }
            continue;
        }
        break;
    }
    let start = *pos;
    while *pos < bytes.len() && !bytes[*pos].is_ascii_whitespace() {
        *pos += 1;
    }
    if start == *pos {
        return Err(Error::Codec("unexpected end of header".into()));
    }
    Ok(String::from_utf8_lossy(&bytes[start..*pos]).into_owned())
}

fn parse_num(tok: &str) -> Result<usize> {
    tok.parse::<usize>().map_err(|_| Error::Codec(format!("bad header number `{tok}`")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_pgm() {
        let img = ImageU8::from_vec(3, 2, vec![0, 64, 128, 192, 255, 10]).unwrap();
        let dir = std::env::temp_dir().join("canny_par_pgm_test");
        let path = dir.join("x.pgm");
        write_pgm(&path, &img).unwrap();
        let back = read_pgm(&path).unwrap();
        assert_eq!(back, img);
    }

    #[test]
    fn decodes_with_comments() {
        let mut bytes = b"P5\n# a comment\n2 2\n255\n".to_vec();
        bytes.extend_from_slice(&[1, 2, 3, 4]);
        let img = decode(&bytes).unwrap();
        assert_eq!(img.data(), &[1, 2, 3, 4]);
    }

    #[test]
    fn scales_maxval() {
        let mut bytes = b"P5\n2 1\n100\n".to_vec();
        bytes.extend_from_slice(&[0, 100]);
        let img = decode(&bytes).unwrap();
        assert_eq!(img.data(), &[0, 255]);
    }

    #[test]
    fn ppm_luma_conversion() {
        let mut bytes = b"P6\n1 1\n255\n".to_vec();
        bytes.extend_from_slice(&[255, 0, 0]); // pure red
        let img = decode(&bytes).unwrap();
        assert_eq!(img.data(), &[76]); // 0.299 * 255
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        assert!(decode(b"P4\n1 1\n255\nx").is_err());
        assert!(decode(b"P5\n4 4\n255\nxy").is_err());
        assert!(decode(b"P5\n2 2\n70000\n____").is_err());
    }
}
