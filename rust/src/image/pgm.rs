//! Binary PGM (P5) / PPM (P6) codec — the no-dependency substitute for
//! the paper's OpenCV image I/O. P5 is the native grayscale format;
//! P6 is read by luma conversion so RGB test assets also work. 16-bit
//! rasters (maxval 256..=65535, big-endian samples per the PNM spec)
//! are accepted and rescaled to 8-bit, so high-bit-depth camera frames
//! feed the stream tier without a conversion step.

use std::fs;
use std::io::Write as _;
use std::path::Path;

use crate::error::{Error, Result};
use crate::image::ImageU8;

/// Write an 8-bit grayscale image as binary PGM (P5).
pub fn write_pgm(path: &Path, img: &ImageU8) -> Result<()> {
    if let Some(parent) = path.parent() {
        fs::create_dir_all(parent)?;
    }
    let mut f = fs::File::create(path)?;
    write!(f, "P5\n{} {}\n255\n", img.width(), img.height())?;
    f.write_all(img.data())?;
    Ok(())
}

/// Read a binary PGM (P5) or PPM (P6, converted to luma) image.
pub fn read_pgm(path: &Path) -> Result<ImageU8> {
    let bytes = fs::read(path)?;
    decode(&bytes)
}

/// Decode from memory. Supports `P5` and `P6` with maxval 1..=65535
/// (16-bit samples are big-endian, per the PNM spec, and rescale to
/// 8-bit).
pub fn decode(bytes: &[u8]) -> Result<ImageU8> {
    let mut pos = 0usize;
    let magic = token(bytes, &mut pos)?;
    let channels = match magic.as_str() {
        "P5" => 1usize,
        "P6" => 3usize,
        other => return Err(Error::Codec(format!("unsupported magic `{other}`"))),
    };
    let width: usize = parse_num(&token(bytes, &mut pos)?)?;
    let height: usize = parse_num(&token(bytes, &mut pos)?)?;
    let maxval: usize = parse_num(&token(bytes, &mut pos)?)?;
    if maxval == 0 || maxval > 65535 {
        return Err(Error::Codec(format!("unsupported maxval {maxval}")));
    }
    let wide = maxval > 255;
    // Exactly one whitespace byte separates the header from raster data.
    pos += 1;
    let samples = width * height * channels;
    let need = samples * if wide { 2 } else { 1 };
    if bytes.len() < pos + need {
        return Err(Error::Codec(format!(
            "truncated raster: need {need} bytes, have {}",
            bytes.len().saturating_sub(pos)
        )));
    }
    let raster = &bytes[pos..pos + need];
    let scale = 255.0 / maxval as f32;
    let rescale = |v: f32| (v * scale).round().min(255.0) as u8;
    // BT.601 luma, the standard grayscale conversion.
    let luma = |r: f32, g: f32, b: f32| rescale(0.299 * r + 0.587 * g + 0.114 * b);
    let wide16 = |b: &[u8]| u16::from_be_bytes([b[0], b[1]]) as f32;
    // Per (sample width, channels) path — no intermediate buffer.
    let data: Vec<u8> = match (wide, channels) {
        (false, 1) => raster.iter().map(|&v| rescale(v as f32)).collect(),
        (false, _) => raster
            .chunks_exact(3)
            .map(|px| luma(px[0] as f32, px[1] as f32, px[2] as f32))
            .collect(),
        (true, 1) => raster.chunks_exact(2).map(|b| rescale(wide16(b))).collect(),
        (true, _) => raster
            .chunks_exact(6)
            .map(|px| luma(wide16(&px[0..2]), wide16(&px[2..4]), wide16(&px[4..6])))
            .collect(),
    };
    ImageU8::from_vec(width, height, data)
}

/// Next whitespace-delimited header token, skipping `#` comments.
fn token(bytes: &[u8], pos: &mut usize) -> Result<String> {
    loop {
        while *pos < bytes.len() && bytes[*pos].is_ascii_whitespace() {
            *pos += 1;
        }
        if *pos < bytes.len() && bytes[*pos] == b'#' {
            while *pos < bytes.len() && bytes[*pos] != b'\n' {
                *pos += 1;
            }
            continue;
        }
        break;
    }
    let start = *pos;
    while *pos < bytes.len() && !bytes[*pos].is_ascii_whitespace() {
        *pos += 1;
    }
    if start == *pos {
        return Err(Error::Codec("unexpected end of header".into()));
    }
    Ok(String::from_utf8_lossy(&bytes[start..*pos]).into_owned())
}

fn parse_num(tok: &str) -> Result<usize> {
    tok.parse::<usize>().map_err(|_| Error::Codec(format!("bad header number `{tok}`")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_pgm() {
        let img = ImageU8::from_vec(3, 2, vec![0, 64, 128, 192, 255, 10]).unwrap();
        let dir = std::env::temp_dir().join("canny_par_pgm_test");
        let path = dir.join("x.pgm");
        write_pgm(&path, &img).unwrap();
        let back = read_pgm(&path).unwrap();
        assert_eq!(back, img);
    }

    #[test]
    fn decodes_with_comments() {
        let mut bytes = b"P5\n# a comment\n2 2\n255\n".to_vec();
        bytes.extend_from_slice(&[1, 2, 3, 4]);
        let img = decode(&bytes).unwrap();
        assert_eq!(img.data(), &[1, 2, 3, 4]);
    }

    #[test]
    fn scales_maxval() {
        let mut bytes = b"P5\n2 1\n100\n".to_vec();
        bytes.extend_from_slice(&[0, 100]);
        let img = decode(&bytes).unwrap();
        assert_eq!(img.data(), &[0, 255]);
    }

    #[test]
    fn ppm_luma_conversion() {
        let mut bytes = b"P6\n1 1\n255\n".to_vec();
        bytes.extend_from_slice(&[255, 0, 0]); // pure red
        let img = decode(&bytes).unwrap();
        assert_eq!(img.data(), &[76]); // 0.299 * 255
    }

    #[test]
    fn decodes_16bit_p5_full_range() {
        // maxval 65535, big-endian samples: 0, 65535, 32768.
        let mut bytes = b"P5\n3 1\n65535\n".to_vec();
        bytes.extend_from_slice(&[0x00, 0x00, 0xff, 0xff, 0x80, 0x00]);
        let img = decode(&bytes).unwrap();
        // 32768 * 255 / 65535 = 127.50195 -> rounds to 128.
        assert_eq!(img.data(), &[0, 255, 128]);
    }

    #[test]
    fn decodes_16bit_p5_odd_maxval() {
        // maxval 1000 (two-byte because > 255): 250/1000 -> 63.75 -> 64.
        let mut bytes = b"P5\n2 1\n1000\n".to_vec();
        bytes.extend_from_slice(&[0x03, 0xe8, 0x00, 0xfa]); // 1000, 250
        let img = decode(&bytes).unwrap();
        assert_eq!(img.data(), &[255, 64]);
    }

    #[test]
    fn decodes_16bit_p6_luma() {
        // Pure red at full 16-bit scale -> same luma as the 8-bit case.
        let mut bytes = b"P6\n1 1\n65535\n".to_vec();
        bytes.extend_from_slice(&[0xff, 0xff, 0x00, 0x00, 0x00, 0x00]);
        let img = decode(&bytes).unwrap();
        assert_eq!(img.data(), &[76]); // 0.299 * 255
    }

    #[test]
    fn truncated_16bit_raster_rejected() {
        // 2x1 at maxval 65535 needs 4 raster bytes; give 3.
        let mut bytes = b"P5\n2 1\n65535\n".to_vec();
        bytes.extend_from_slice(&[0x00, 0x01, 0x02]);
        assert!(decode(&bytes).is_err());
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        assert!(decode(b"P4\n1 1\n255\nx").is_err());
        assert!(decode(b"P5\n4 4\n255\nxy").is_err());
        // Malformed headers: maxval beyond 16-bit, non-numeric width,
        // and a header that ends before maxval.
        assert!(decode(b"P5\n2 2\n70000\n____").is_err());
        assert!(decode(b"P5\nwide 2\n255\nxxxx").is_err());
        assert!(decode(b"P5\n2 2\n").is_err());
    }
}
