//! Procedural scene generators: the substitute for the paper's OpenCV
//! test-image set (DESIGN.md §3). Each scene targets a workload class
//! from the paper's motivation:
//!
//! * [`Scene::Shapes`] — geometric objects with crisp boundaries; the
//!   classic edge-detection demo (paper Fig. 7).
//! * [`Scene::RemoteSensing`] — terrain-like low-frequency field +
//!   point noise; the Ali & Clausi remote-sensing use case (paper ref 7).
//! * [`Scene::Text`] — dense small glyph-like rectangles; the
//!   steganography / document IFE workload (paper ref 9).
//! * [`Scene::Checker`] — periodic high-density edges; worst-case edge
//!   density for throughput stress.
//! * [`Scene::Gradient`] — smooth ramp; zero true edges (false-positive
//!   probe).
//! * [`Scene::Video`] — [`Scene::Shapes`] with a time parameter for the
//!   streaming example's moving objects.

use crate::image::ImageF32;
use crate::util::Prng;

/// Available synthetic scenes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Scene {
    Shapes { seed: u64 },
    RemoteSensing { seed: u64, noise: f32 },
    Text { seed: u64 },
    Checker { cell: usize },
    Gradient,
    Video { seed: u64, frame: usize },
}

impl Scene {
    /// Parse a scene name as used by the CLI (`--scene shapes:7`).
    /// Video takes a two-part argument — `video:<seed>:<frame>` — so
    /// `cannyd run --scene`, `cannyd batch` and the stream tier's
    /// [`crate::stream::FrameSource`] all share this one parser
    /// (`video` = seed 7 frame 0, `video:3` = seed 3 frame 0).
    pub fn parse(spec: &str) -> Option<Scene> {
        let (name, arg) = match spec.split_once(':') {
            Some((n, a)) => (n, Some(a)),
            None => (spec, None),
        };
        let num = |d: u64| arg.and_then(|a| a.parse::<u64>().ok()).unwrap_or(d);
        match name {
            "shapes" => Some(Scene::Shapes { seed: num(7) }),
            "remote" | "remote-sensing" => {
                Some(Scene::RemoteSensing { seed: num(7), noise: 0.08 })
            }
            "text" => Some(Scene::Text { seed: num(7) }),
            "checker" => Some(Scene::Checker { cell: num(16) as usize }),
            "gradient" => Some(Scene::Gradient),
            "video" => {
                let (seed, frame) = match arg {
                    None => (7, 0),
                    Some(a) => {
                        let (s, f) = match a.split_once(':') {
                            Some((s, f)) => (s, Some(f)),
                            None => (a, None),
                        };
                        (
                            s.parse::<u64>().unwrap_or(7),
                            f.and_then(|f| f.parse::<usize>().ok()).unwrap_or(0),
                        )
                    }
                };
                Some(Scene::Video { seed, frame })
            }
            _ => None,
        }
    }

    /// Render the spec string [`Scene::parse`] accepts back from the
    /// value — the wire form the cluster tier ships requests as, and
    /// the content label the routing ring hashes. Round-trips through
    /// `parse` for every scene a trace can carry (`RemoteSensing`
    /// keeps the parser's fixed noise; `parse` never reads noise from
    /// the spec).
    pub fn spec(&self) -> String {
        match self {
            Scene::Shapes { seed } => format!("shapes:{seed}"),
            Scene::RemoteSensing { seed, .. } => format!("remote:{seed}"),
            Scene::Text { seed } => format!("text:{seed}"),
            Scene::Checker { cell } => format!("checker:{cell}"),
            Scene::Gradient => "gradient".into(),
            Scene::Video { seed, frame } => format!("video:{seed}:{frame}"),
        }
    }
}

/// Generate a scene at the given size.
pub fn generate(scene: Scene, width: usize, height: usize) -> ImageF32 {
    match scene {
        Scene::Shapes { seed } => shapes(width, height, seed, 0),
        Scene::RemoteSensing { seed, noise } => remote_sensing(width, height, seed, noise),
        Scene::Text { seed } => text(width, height, seed),
        Scene::Checker { cell } => checker(width, height, cell.max(1)),
        Scene::Gradient => gradient(width, height),
        Scene::Video { seed, frame } => shapes(width, height, seed, frame),
    }
}

fn shapes(w: usize, h: usize, seed: u64, frame: usize) -> ImageF32 {
    let mut img = ImageF32::zeros(w, h);
    // Soft background vignette so the scene is not trivially flat.
    for y in 0..h {
        for x in 0..w {
            let fx = x as f32 / w.max(1) as f32 - 0.5;
            let fy = y as f32 / h.max(1) as f32 - 0.5;
            img.set(y, x, 0.25 + 0.1 * (1.0 - (fx * fx + fy * fy)));
        }
    }
    let mut rng = Prng::new(seed);
    let n = 6 + rng.next_below(6);
    let drift = frame as f32 * 2.5;
    for k in 0..n {
        let cx = rng.next_below(w.max(1)) as f32 + drift * if k % 2 == 0 { 1.0 } else { -1.0 };
        let cy = rng.next_below(h.max(1)) as f32 + drift * 0.5;
        let r = (6 + rng.next_below(w.max(12) / 6)) as f32;
        let val = 0.55 + 0.45 * rng.next_f32();
        let rect = rng.next_below(3) == 0;
        let (x0, x1) = ((cx - r).max(0.0) as usize, ((cx + r) as usize).min(w));
        let (y0, y1) = ((cy - r).max(0.0) as usize, ((cy + r) as usize).min(h));
        for y in y0..y1 {
            for x in x0..x1 {
                let dx = x as f32 - cx;
                let dy = y as f32 - cy;
                let inside = if rect {
                    dx.abs() <= r * 0.8 && dy.abs() <= r * 0.55
                } else {
                    dx * dx + dy * dy <= r * r
                };
                if inside {
                    img.set(y, x, val);
                }
            }
        }
    }
    img
}

fn remote_sensing(w: usize, h: usize, seed: u64, noise: f32) -> ImageF32 {
    let mut rng = Prng::new(seed);
    let mut img = ImageF32::zeros(w, h);
    // Low-frequency "terrain" as a sum of a few random plane waves,
    // thresholded into patches (field / water / urban analogue).
    let waves: Vec<(f32, f32, f32)> = (0..4)
        .map(|_| {
            (
                0.02 + 0.08 * rng.next_f32(),
                0.02 + 0.08 * rng.next_f32(),
                std::f32::consts::TAU * rng.next_f32(),
            )
        })
        .collect();
    for y in 0..h {
        for x in 0..w {
            let mut v = 0.0f32;
            for &(kx, ky, ph) in &waves {
                v += (kx * x as f32 + ky * y as f32 + ph).sin();
            }
            // Quantize to 3 plateaus -> real region boundaries to detect.
            let plateau = if v > 1.0 {
                0.8
            } else if v > -1.0 {
                0.5
            } else {
                0.2
            };
            img.set(y, x, plateau);
        }
    }
    // Point (salt-and-pepper-ish gaussian) noise, the paper's [7] theme.
    for v in img.data_mut() {
        *v = (*v + noise * rng.next_gaussian()).clamp(0.0, 1.0);
    }
    img
}

fn text(w: usize, h: usize, seed: u64) -> ImageF32 {
    let mut img = ImageF32::zeros(w, h);
    for v in img.data_mut() {
        *v = 0.92; // paper-white page
    }
    let mut rng = Prng::new(seed);
    let line_h = 12usize;
    let mut y = 4usize;
    while y + line_h < h {
        let mut x = 4usize;
        while x + 10 < w {
            let glyph_w = 3 + rng.next_below(6);
            if rng.next_f32() < 0.82 {
                // A "glyph": dark rectangle with a random notch.
                let gh = 5 + rng.next_below(5);
                let notch = rng.next_below(glyph_w.max(1));
                for gy in 0..gh.min(line_h) {
                    for gx in 0..glyph_w {
                        if gx == notch && gy > 1 {
                            continue;
                        }
                        if y + gy < h && x + gx < w {
                            img.set(y + gy, x + gx, 0.08);
                        }
                    }
                }
            }
            x += glyph_w + 2 + rng.next_below(3);
        }
        y += line_h + 2;
    }
    img
}

fn checker(w: usize, h: usize, cell: usize) -> ImageF32 {
    let mut img = ImageF32::zeros(w, h);
    for y in 0..h {
        for x in 0..w {
            let v = ((x / cell) + (y / cell)) % 2;
            img.set(y, x, if v == 0 { 0.15 } else { 0.85 });
        }
    }
    img
}

fn gradient(w: usize, h: usize) -> ImageF32 {
    let mut img = ImageF32::zeros(w, h);
    for y in 0..h {
        for x in 0..w {
            img.set(y, x, (x + y) as f32 / (w + h).max(1) as f32);
        }
    }
    img
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_scenes_generate_in_range() {
        for scene in [
            Scene::Shapes { seed: 1 },
            Scene::RemoteSensing { seed: 1, noise: 0.1 },
            Scene::Text { seed: 1 },
            Scene::Checker { cell: 8 },
            Scene::Gradient,
            Scene::Video { seed: 1, frame: 3 },
        ] {
            let img = generate(scene, 64, 48);
            assert_eq!(img.width(), 64);
            assert_eq!(img.height(), 48);
            let (lo, hi) = img.min_max();
            assert!(lo >= 0.0 && hi <= 1.0, "{scene:?} out of range: {lo}..{hi}");
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let a = generate(Scene::Shapes { seed: 42 }, 100, 80);
        let b = generate(Scene::Shapes { seed: 42 }, 100, 80);
        assert_eq!(a, b);
    }

    #[test]
    fn seeds_change_content() {
        let a = generate(Scene::Shapes { seed: 1 }, 64, 64);
        let b = generate(Scene::Shapes { seed: 2 }, 64, 64);
        assert_ne!(a, b);
    }

    #[test]
    fn video_frames_move() {
        let f0 = generate(Scene::Video { seed: 3, frame: 0 }, 64, 64);
        let f5 = generate(Scene::Video { seed: 3, frame: 5 }, 64, 64);
        assert_ne!(f0, f5);
    }

    #[test]
    fn checker_has_expected_contrast() {
        let img = generate(Scene::Checker { cell: 4 }, 16, 16);
        assert_eq!(img.get(0, 0), 0.15);
        assert_eq!(img.get(0, 4), 0.85);
        assert_eq!(img.get(4, 4), 0.15);
    }

    #[test]
    fn parse_cli_names() {
        assert_eq!(Scene::parse("shapes:9"), Some(Scene::Shapes { seed: 9 }));
        assert_eq!(Scene::parse("gradient"), Some(Scene::Gradient));
        assert_eq!(Scene::parse("checker:32"), Some(Scene::Checker { cell: 32 }));
        assert!(Scene::parse("nope").is_none());
    }

    #[test]
    fn spec_round_trips_through_parse() {
        for scene in [
            Scene::Shapes { seed: 9 },
            Scene::RemoteSensing { seed: 3, noise: 0.08 },
            Scene::Text { seed: 4 },
            Scene::Checker { cell: 32 },
            Scene::Gradient,
            Scene::Video { seed: 5, frame: 12 },
        ] {
            assert_eq!(Scene::parse(&scene.spec()), Some(scene), "{scene:?}");
        }
    }

    #[test]
    fn parse_video_seed_and_frame() {
        assert_eq!(Scene::parse("video"), Some(Scene::Video { seed: 7, frame: 0 }));
        assert_eq!(Scene::parse("video:3"), Some(Scene::Video { seed: 3, frame: 0 }));
        assert_eq!(Scene::parse("video:3:12"), Some(Scene::Video { seed: 3, frame: 12 }));
        // The spec the stream source generates per frame.
        let a = generate(Scene::parse("video:5:2").unwrap(), 48, 32);
        let b = generate(Scene::Video { seed: 5, frame: 2 }, 48, 32);
        assert_eq!(a, b);
    }
}
