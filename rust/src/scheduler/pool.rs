//! The work-stealing pool and structured fork–join scope.

use std::collections::VecDeque;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::error::{Error, Result};
use crate::scheduler::stats::PoolStats;
use crate::util::timer::Stopwatch;
use crate::util::Prng;

type Task = Box<dyn FnOnce() + Send + 'static>;

/// Unique pool ids so nested scopes can tell "am I a worker of *this*
/// pool" (worker threads help-join instead of blocking).
static POOL_IDS: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// (pool id, worker index) when the current thread is a pool worker.
    static WORKER: std::cell::Cell<Option<(u64, usize)>> = const { std::cell::Cell::new(None) };
}

struct Shared {
    id: u64,
    /// Per-worker deques: owner pops back (LIFO), thieves pop front (FIFO).
    deques: Vec<Mutex<VecDeque<Task>>>,
    /// Overflow queue for tasks submitted from non-worker threads.
    injector: Mutex<VecDeque<Task>>,
    /// Sleep/wake for idle workers.
    idle_lock: Mutex<()>,
    idle_cv: Condvar,
    /// Approximate count of queued tasks (wake hint).
    queued: AtomicUsize,
    shutdown: AtomicBool,
    stats: PoolStats,
}

impl Shared {
    fn push_local(&self, me: usize, task: Task) {
        self.deques[me].lock().unwrap().push_back(task);
        self.queued.fetch_add(1, Ordering::Release);
        self.idle_cv.notify_one();
    }

    /// Kept for completeness (cross-pool submission without steal
    /// semantics); the scope path prefers deque 0 — see `Scope::spawn`.
    #[allow(dead_code)]
    fn push_injector(&self, task: Task) {
        self.injector.lock().unwrap().push_back(task);
        self.queued.fetch_add(1, Ordering::Release);
        self.idle_cv.notify_all();
    }

    /// Owner-side LIFO pop.
    fn pop_local(&self, me: usize) -> Option<Task> {
        let t = self.deques[me].lock().unwrap().pop_back();
        if t.is_some() {
            self.queued.fetch_sub(1, Ordering::AcqRel);
        }
        t
    }

    /// Thief-side FIFO steal from `victim`.
    fn steal_from(&self, victim: usize) -> Option<Task> {
        let t = self.deques[victim].lock().unwrap().pop_front();
        if t.is_some() {
            self.queued.fetch_sub(1, Ordering::AcqRel);
        }
        t
    }

    fn pop_injector(&self) -> Option<Task> {
        let t = self.injector.lock().unwrap().pop_front();
        if t.is_some() {
            self.queued.fetch_sub(1, Ordering::AcqRel);
        }
        t
    }

    /// Find any task: local LIFO first, then injector, then random-order
    /// steals. `me == None` for external helpers (no local deque).
    fn find_task(&self, me: Option<usize>, rng: &mut Prng) -> Option<Task> {
        if let Some(me) = me {
            if let Some(t) = self.pop_local(me) {
                return Some(t);
            }
        }
        if let Some(t) = self.pop_injector() {
            return Some(t);
        }
        let n = self.deques.len();
        if n == 0 {
            return None;
        }
        let start = rng.next_below(n);
        for k in 0..n {
            let victim = (start + k) % n;
            if Some(victim) == me {
                continue;
            }
            if let Some(t) = self.steal_from(victim) {
                if let Some(me) = me {
                    self.stats.worker(me).steals.fetch_add(1, Ordering::Relaxed);
                }
                return Some(t);
            }
        }
        None
    }
}

/// A work-stealing thread pool (Cilk-style runtime).
pub struct Pool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    n_workers: usize,
}

// Manual impl: `Shared` holds deques of opaque task closures.
impl std::fmt::Debug for Pool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Pool").field("n_workers", &self.n_workers).finish()
    }
}

impl Pool {
    /// Spawn a pool with `n_workers` worker threads (>= 1).
    pub fn new(n_workers: usize) -> Result<Pool> {
        if n_workers == 0 {
            return Err(Error::Scheduler("pool needs >= 1 worker".into()));
        }
        let shared = Arc::new(Shared {
            id: POOL_IDS.fetch_add(1, Ordering::Relaxed),
            deques: (0..n_workers).map(|_| Mutex::new(VecDeque::new())).collect(),
            injector: Mutex::new(VecDeque::new()),
            idle_lock: Mutex::new(()),
            idle_cv: Condvar::new(),
            queued: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            stats: PoolStats::new(n_workers),
        });
        let handles = (0..n_workers)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("canny-worker-{i}"))
                    .spawn(move || worker_loop(shared, i))
                    .expect("spawn worker")
            })
            .collect();
        Ok(Pool { shared, handles, n_workers })
    }

    /// Number of workers.
    pub fn n_workers(&self) -> usize {
        self.n_workers
    }

    /// Live stats handle for the profiler.
    pub fn stats(&self) -> PoolStats {
        self.shared.stats.clone()
    }

    /// Structured fork–join: tasks spawned on the scope are guaranteed
    /// complete when `scope` returns. Panics in tasks propagate.
    pub fn scope<'env, F, R>(&self, f: F) -> R
    where
        F: FnOnce(&Scope<'env>) -> R,
    {
        let inner = Arc::new(ScopeInner {
            pending: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
            lock: Mutex::new(()),
            cv: Condvar::new(),
        });
        let scope = Scope {
            shared: Arc::clone(&self.shared),
            inner,
            _marker: std::marker::PhantomData,
        };
        let result = f(&scope);
        scope.join();
        result
    }

    /// Convenience: run one closure on the pool and wait.
    pub fn run<R: Send>(&self, f: impl FnOnce() -> R + Send) -> R {
        let mut out: Option<R> = None;
        self.scope(|s| {
            let slot = &mut out;
            s.spawn(move || {
                *slot = Some(f());
            });
        });
        out.expect("task ran")
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.idle_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

struct ScopeInner {
    pending: AtomicUsize,
    panicked: AtomicBool,
    lock: Mutex<()>,
    cv: Condvar,
}

/// Fork–join scope handle. Lifetime `'env` allows spawned closures to
/// borrow from the enclosing environment (like `std::thread::scope`).
pub struct Scope<'env> {
    shared: Arc<Shared>,
    inner: Arc<ScopeInner>,
    _marker: std::marker::PhantomData<&'env mut &'env ()>,
}

// Manual impl: both fields are opaque scheduler state.
impl std::fmt::Debug for Scope<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Scope").finish_non_exhaustive()
    }
}

impl<'env> Scope<'env> {
    /// Spawn a task into the pool (`cilk_spawn`).
    pub fn spawn<F>(&self, f: F)
    where
        F: FnOnce() + Send + 'env,
    {
        self.inner.pending.fetch_add(1, Ordering::AcqRel);
        let inner = Arc::clone(&self.inner);
        let task: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
            let ok = panic::catch_unwind(AssertUnwindSafe(f)).is_ok();
            if !ok {
                inner.panicked.store(true, Ordering::Release);
            }
            if inner.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
                let _g = inner.lock.lock().unwrap();
                inner.cv.notify_all();
            }
        });
        // SAFETY: `join()` runs before the scope (and thus `'env`) ends,
        // so the closure cannot outlive its borrows. Same argument as
        // std::thread::scope / rayon::scope.
        let task: Task = unsafe { std::mem::transmute(task) };
        let me = WORKER.with(|w| w.get());
        match me {
            Some((pool_id, idx)) if pool_id == self.shared.id => {
                self.shared.push_local(idx, task)
            }
            // External spawner: hand the task to worker 0's deque — the
            // Cilk model (the spawner's deque, stolen FIFO by idle
            // workers) and what the simulator replays. The injector is
            // reserved for tasks that must not be stolen ordering-wise.
            _ => self.shared.push_local(0, task),
        }
    }

    /// Wait for all spawned tasks (`cilk_sync`). Called automatically at
    /// scope exit. Worker threads *help* (run tasks) instead of blocking
    /// so nested scopes cannot deadlock a small pool.
    fn join(&self) {
        let me = WORKER.with(|w| w.get());
        let helping_idx = match me {
            Some((pool_id, idx)) if pool_id == self.shared.id => Some(idx),
            _ => None,
        };
        if helping_idx.is_some() {
            let me = helping_idx.unwrap();
            let mut rng = Prng::new(0x5EED ^ me as u64);
            while self.inner.pending.load(Ordering::Acquire) > 0 {
                if let Some(task) = self.shared.find_task(helping_idx, &mut rng) {
                    // Count the task; busy time is already covered by the
                    // enclosing task this worker is inside of.
                    self.shared.stats.worker(me).tasks.fetch_add(1, Ordering::Relaxed);
                    task();
                } else {
                    std::thread::yield_now();
                }
            }
        } else {
            // External thread: block until the workers drain the scope.
            // (Deliberately no external help: ALL task execution happens
            // on pool workers so per-worker stats account for every task,
            // matching the paper's per-core utilization accounting.)
            while self.inner.pending.load(Ordering::Acquire) > 0 {
                let g = self.inner.lock.lock().unwrap();
                if self.inner.pending.load(Ordering::Acquire) > 0 {
                    let _ = self
                        .inner
                        .cv
                        .wait_timeout(g, Duration::from_millis(1))
                        .unwrap();
                }
            }
        }
        if self.inner.panicked.load(Ordering::Acquire) {
            panic!("a task spawned in Pool::scope panicked");
        }
    }
}

fn worker_loop(shared: Arc<Shared>, me: usize) {
    WORKER.with(|w| w.set(Some((shared.id, me))));
    let mut rng = Prng::new(0x57EA1u64 ^ (me as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    loop {
        if let Some(task) = shared.find_task(Some(me), &mut rng) {
            let stats = shared.stats.worker(me);
            stats.busy.store(true, Ordering::Relaxed);
            // Counted BEFORE execution: the task body performs the
            // scope-join notification, so post-hoc accounting would race
            // with an observer that wakes on "all tasks done".
            stats.tasks.fetch_add(1, Ordering::Relaxed);
            let sw = Stopwatch::start();
            task();
            stats.busy_ns.fetch_add(sw.elapsed_ns(), Ordering::Relaxed);
            stats.busy.store(false, Ordering::Relaxed);
            continue;
        }
        if shared.shutdown.load(Ordering::Acquire) {
            break;
        }
        // Nothing to do: sleep until new work or shutdown.
        let g = shared.idle_lock.lock().unwrap();
        if shared.queued.load(Ordering::Acquire) == 0
            && !shared.shutdown.load(Ordering::Acquire)
        {
            let _ = shared.idle_cv.wait_timeout(g, Duration::from_millis(1)).unwrap();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn runs_all_tasks_exactly_once() {
        let pool = Pool::new(4).unwrap();
        let counter = AtomicU32::new(0);
        pool.scope(|s| {
            for _ in 0..1000 {
                s.spawn(|| {
                    counter.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn scope_borrows_environment() {
        let pool = Pool::new(2).unwrap();
        let mut results = vec![0usize; 8];
        {
            let chunks: Vec<&mut [usize]> = results.chunks_mut(2).collect();
            pool.scope(|s| {
                for (k, chunk) in chunks.into_iter().enumerate() {
                    s.spawn(move || {
                        for (j, slot) in chunk.iter_mut().enumerate() {
                            *slot = k * 10 + j;
                        }
                    });
                }
            });
        }
        assert_eq!(results, vec![0, 1, 10, 11, 20, 21, 30, 31]);
    }

    #[test]
    fn nested_scopes_do_not_deadlock() {
        let pool = Pool::new(1).unwrap(); // single worker is the hard case
        let counter = AtomicU32::new(0);
        pool.scope(|s| {
            for _ in 0..4 {
                let counter = &counter;
                s.spawn(move || {
                    // This runs ON the only worker; the inner scope must
                    // help-join rather than block.
                    WORKER.with(|w| assert!(w.get().is_some()));
                    counter.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(counter.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn run_returns_value() {
        let pool = Pool::new(2).unwrap();
        assert_eq!(pool.run(|| 6 * 7), 42);
    }

    #[test]
    #[should_panic(expected = "task spawned in Pool::scope panicked")]
    fn task_panic_propagates() {
        let pool = Pool::new(2).unwrap();
        pool.scope(|s| {
            s.spawn(|| panic!("boom"));
        });
    }

    #[test]
    fn stats_accumulate() {
        let pool = Pool::new(2).unwrap();
        pool.scope(|s| {
            for _ in 0..64 {
                s.spawn(|| {
                    std::hint::black_box((0..10_000u64).sum::<u64>());
                });
            }
        });
        let stats = pool.stats();
        assert_eq!(stats.total_tasks(), 64);
        assert!(stats.total_busy_ns() > 0);
    }

    #[test]
    fn zero_workers_rejected() {
        assert!(Pool::new(0).is_err());
    }

    #[test]
    fn pool_drop_terminates() {
        let pool = Pool::new(3).unwrap();
        pool.scope(|s| {
            s.spawn(|| ());
        });
        drop(pool); // must not hang
    }
}
