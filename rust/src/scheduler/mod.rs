//! Cilk-style work-stealing thread pool — the substitute for the Intel
//! Cilk Plus runtime the paper builds on (DESIGN.md §3).
//!
//! Semantics mirrored from Cilk:
//! * each worker owns a deque; it pushes/pops its own work LIFO
//!   (depth-first, cache-friendly),
//! * idle workers steal FIFO from a random victim (breadth-first,
//!   load-balancing — the mechanism behind the paper's "even
//!   distribution of work across all cores", Fig. 3/11/12),
//! * `scope` provides structured fork–join (`cilk_spawn`/`cilk_sync`).
//!
//! The deques are mutex-protected rather than lock-free Chase–Lev:
//! task granularity in this system is an image tile or row band
//! (tens of µs to ms), so deque overhead is noise, and the mutex
//! version is auditable. Per-worker [`stats::WorkerStats`] feed the
//! sampling profiler (Figures 8–12).

pub mod pool;
pub mod stats;

pub use pool::{Pool, Scope};
pub use stats::{PoolStats, WorkerStats};
