//! Per-worker execution statistics: the raw signal behind the paper's
//! CPU-utilization figures. The sampling profiler reads `busy` flags
//! and cumulative busy-ns while workers run.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

/// Live, shareable stats for one worker.
#[derive(Debug, Default)]
pub struct WorkerStats {
    /// Currently executing a task?
    pub busy: AtomicBool,
    /// Total nanoseconds spent inside tasks.
    pub busy_ns: AtomicU64,
    /// Tasks executed.
    pub tasks: AtomicU64,
    /// Successful steals performed by this worker.
    pub steals: AtomicU64,
}

impl WorkerStats {
    pub fn snapshot(&self) -> WorkerSnapshot {
        WorkerSnapshot {
            busy: self.busy.load(Ordering::Relaxed),
            busy_ns: self.busy_ns.load(Ordering::Relaxed),
            tasks: self.tasks.load(Ordering::Relaxed),
            steals: self.steals.load(Ordering::Relaxed),
        }
    }
}

/// A point-in-time copy of one worker's counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WorkerSnapshot {
    pub busy: bool,
    pub busy_ns: u64,
    pub tasks: u64,
    pub steals: u64,
}

/// Shared handle to all workers' stats (what the profiler samples).
#[derive(Clone, Debug)]
pub struct PoolStats {
    workers: Arc<Vec<WorkerStats>>,
}

impl PoolStats {
    pub(crate) fn new(n: usize) -> PoolStats {
        PoolStats { workers: Arc::new((0..n).map(|_| WorkerStats::default()).collect()) }
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    pub(crate) fn worker(&self, i: usize) -> &WorkerStats {
        &self.workers[i]
    }

    /// Snapshot every worker.
    pub fn snapshot(&self) -> Vec<WorkerSnapshot> {
        self.workers.iter().map(|w| w.snapshot()).collect()
    }

    /// Total busy nanoseconds across workers.
    pub fn total_busy_ns(&self) -> u64 {
        self.workers.iter().map(|w| w.busy_ns.load(Ordering::Relaxed)).sum()
    }

    /// Total tasks executed across workers.
    pub fn total_tasks(&self) -> u64 {
        self.workers.iter().map(|w| w.tasks.load(Ordering::Relaxed)).sum()
    }

    /// Total successful steals across workers.
    pub fn total_steals(&self) -> u64 {
        self.workers.iter().map(|w| w.steals.load(Ordering::Relaxed)).sum()
    }

    /// Reset counters (between bench iterations).
    pub fn reset(&self) {
        for w in self.workers.iter() {
            w.busy_ns.store(0, Ordering::Relaxed);
            w.tasks.store(0, Ordering::Relaxed);
            w.steals.store(0, Ordering::Relaxed);
        }
    }

    /// Per-worker busy-ns vector (Figure 3's load histogram).
    pub fn busy_ns_per_worker(&self) -> Vec<u64> {
        self.workers.iter().map(|w| w.busy_ns.load(Ordering::Relaxed)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_updates() {
        let stats = PoolStats::new(2);
        stats.worker(0).busy_ns.fetch_add(100, Ordering::Relaxed);
        stats.worker(0).tasks.fetch_add(1, Ordering::Relaxed);
        stats.worker(1).steals.fetch_add(3, Ordering::Relaxed);
        let snap = stats.snapshot();
        assert_eq!(snap[0].busy_ns, 100);
        assert_eq!(snap[0].tasks, 1);
        assert_eq!(snap[1].steals, 3);
        assert_eq!(stats.total_busy_ns(), 100);
        assert_eq!(stats.total_steals(), 3);
    }

    #[test]
    fn reset_clears() {
        let stats = PoolStats::new(1);
        stats.worker(0).busy_ns.fetch_add(5, Ordering::Relaxed);
        stats.reset();
        assert_eq!(stats.total_busy_ns(), 0);
    }
}
