//! Ablations for the DESIGN.md design choices:
//!   A1 tile size sweep (fused-tile engine)
//!   A2 band grain sweep (stage-parallel engine)
//!   A3 engine comparison (patterns vs tiled vs xla)
//!   A4 serial vs parallel hysteresis at varying edge density
//!
//! Run: `cargo bench --bench ablation_patterns`

use canny_par::bench::{bench, Table};
use canny_par::canny::{hysteresis, CannyParams, CannyPipeline};
use canny_par::image::synth::{generate, Scene};
use canny_par::runtime::{Manifest, XlaEngine};
use canny_par::scheduler::Pool;
use canny_par::util::timer::human_ns;

fn main() {
    let img = generate(Scene::Shapes { seed: 7 }, 1024, 768);
    let pool = Pool::new(4).unwrap();

    // A1: tile size sweep.
    let mut t1 = Table::new(&["tile", "median", "tiles", "note"]);
    for tile in [32usize, 64, 128, 256, 512] {
        let params = CannyParams { tile, ..CannyParams::default() };
        let s = bench(1, 5, || CannyPipeline::tiled(&pool).detect(&img, &params).unwrap());
        let tiles = img.width().div_ceil(tile) * img.height().div_ceil(tile);
        let halo_overhead =
            ((tile + 8) * (tile + 8)) as f64 / (tile * tile) as f64 - 1.0;
        t1.row(&[
            tile.to_string(),
            s.human_median(),
            tiles.to_string(),
            format!("halo overhead {:.0}%", 100.0 * halo_overhead),
        ]);
    }
    println!("A1 — tile size (tiled engine, 4 workers):");
    t1.print();

    // A2: band grain sweep for the stage-parallel engine.
    let mut t2 = Table::new(&["band grain", "median"]);
    for grain in [1usize, 8, 32, 96, 384] {
        let params = CannyParams { band_grain: grain, ..CannyParams::default() };
        let s = bench(1, 5, || CannyPipeline::patterns(&pool).detect(&img, &params).unwrap());
        t2.row(&[grain.to_string(), s.human_median()]);
    }
    println!("\nA2 — row-band grain (patterns engine):");
    t2.print();

    // A3: engine comparison.
    let params = CannyParams::default();
    let xla = Manifest::load(&Manifest::default_dir())
        .and_then(|m| XlaEngine::from_manifest(&m, "t128", 4))
        .ok();
    let mut t3 = Table::new(&["engine", "median"]);
    let s = bench(1, 5, || CannyPipeline::serial().detect(&img, &params).unwrap());
    t3.row(&["serial".into(), s.human_median()]);
    let s = bench(1, 5, || CannyPipeline::patterns(&pool).detect(&img, &params).unwrap());
    t3.row(&["patterns".into(), s.human_median()]);
    let s = bench(1, 5, || CannyPipeline::tiled(&pool).detect(&img, &params).unwrap());
    t3.row(&["tiled".into(), s.human_median()]);
    if let Some(x) = xla.as_ref() {
        let p = CannyPipeline::xla(&pool, x);
        let s = bench(1, 3, || p.detect(&img, &params).unwrap());
        t3.row(&["xla (PJRT fused front)".into(), s.human_median()]);
    } else {
        println!("(no artifacts — xla row skipped)");
    }
    println!("\nA3 — engine comparison (1024x768, 4 workers):");
    t3.print();

    // A4: hysteresis serial vs parallel across edge densities.
    let mut t4 = Table::new(&["scene", "edge density", "serial", "parallel", "speedup"]);
    for (name, scene) in [
        ("gradient (sparse)", Scene::Gradient),
        ("shapes (medium)", Scene::Shapes { seed: 7 }),
        ("checker (dense)", Scene::Checker { cell: 8 }),
    ] {
        let im = generate(scene, 768, 768);
        let out = CannyPipeline::serial().detect(&im, &params).unwrap();
        let cls = out.class_map;
        let ss = bench(1, 5, || hysteresis::hysteresis_serial(&cls));
        let pp = bench(1, 5, || hysteresis::hysteresis_parallel(&pool, &cls));
        t4.row(&[
            name.to_string(),
            format!("{:.2}%", 100.0 * out.edges.edge_density()),
            human_ns(ss.median_ns),
            human_ns(pp.median_ns),
            format!("{:.2}x", ss.median_ns as f64 / pp.median_ns as f64),
        ]);
    }
    println!("\nA4 — hysteresis: paper's serial walk vs parallel extension:");
    t4.print();
    println!("\n(note: wall-clock on a 1-CPU host; structural costs — tile counts,");
    println!(" halo overhead, task counts — are host-independent)");
}
