//! Table 1 + the headline scalability claim: speedup and parallel
//! efficiency of the patterns CED across the paper's topologies
//! (i3 = 4 CPUs, i7 = 8 CPUs) plus the §4 future-work manycore probe
//! (32/64 CPUs), from measured tile costs replayed in the simulator.
//!
//! Run: `cargo bench --bench table1_scaling`

use canny_par::amdahl;
use canny_par::bench::Table;
use canny_par::canny::{CannyParams, CannyPipeline};
use canny_par::coordinator::RunReport;
use canny_par::image::synth::{generate, Scene};
use canny_par::scheduler::Pool;
use canny_par::simsched::simulate;

fn main() {
    let img = generate(Scene::Shapes { seed: 7 }, 1024, 1024);
    let pool = Pool::new(2).unwrap();
    let params = CannyParams { tile: 128, ..CannyParams::default() };
    // Use parallel hysteresis? No: the paper keeps it serial — Table 1's
    // scaling includes that Amdahl tax, and the ablation bench shows the
    // alternative.
    let out = CannyPipeline::tiled(&pool).detect(&img, &params).unwrap();
    let spec = RunReport::from_run("tiled", img.len(), &out.times, None).to_sim_spec();
    let serial_frac = spec.serial_fraction();
    let f = 1.0 - serial_frac;

    let t1 = simulate(&spec, 1).makespan_ns as f64;
    let mut table = Table::new(&[
        "topology", "CPUs", "speedup", "efficiency", "Amdahl bound", "achieved/bound",
    ]);
    let rows: Vec<(&str, usize)> = vec![
        ("serial baseline", 1),
        ("Core i3 (Table 1)", 4),
        ("Core i7 (Table 1)", 8),
        ("future work §4", 32),
        ("future work §4", 64),
    ];
    for (name, cpus) in rows {
        let tn = simulate(&spec, cpus).makespan_ns as f64;
        let s = t1 / tn;
        let bound = amdahl::speedup_symmetric(f, cpus);
        table.row(&[
            name.to_string(),
            cpus.to_string(),
            format!("{s:.2}x"),
            format!("{:.0}%", 100.0 * s / cpus as f64),
            format!("{bound:.2}x"),
            format!("{:.0}%", 100.0 * s / bound),
        ]);
    }
    println!("Table 1 reproduction — parallel CED scaling (1024x1024 scene,");
    println!("measured tile costs, simulated topologies; serial fraction {:.1}%):\n", 100.0 * serial_frac);
    table.print();
    let s8 = t1 / simulate(&spec, 8).makespan_ns as f64;
    println!(
        "\nKarp-Flatt fit from 8-CPU point: parallel fraction f = {:.3}",
        amdahl::fit_parallel_fraction(s8, 8)
    );
    println!("paper claim: \"scales well for multicore processors\" — achieved/bound near 100%");
    println!("shows the pattern runtime adds no scheduling bottleneck beyond Amdahl.");
}
