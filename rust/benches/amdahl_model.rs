//! §2.2.1 Amdahl analysis: the paper's asymmetric-multicore speedup
//! equation evaluated against the measured pipeline (serial hysteresis
//! as the 1-f), plus model curves showing when the paper's recommended
//! asymmetric design wins.
//!
//! Run: `cargo bench --bench amdahl_model`

use canny_par::amdahl::{
    best_asymmetric_r, curve, fit_parallel_fraction, speedup_asymmetric, speedup_symmetric,
};
use canny_par::bench::Table;
use canny_par::canny::{CannyParams, CannyPipeline};
use canny_par::coordinator::RunReport;
use canny_par::image::synth::{generate, Scene};
use canny_par::scheduler::Pool;
use canny_par::simsched::simulate;

fn main() {
    // Measured parallel fraction from the real pipeline.
    let img = generate(Scene::Shapes { seed: 7 }, 1024, 1024);
    let pool = Pool::new(2).unwrap();
    let params = CannyParams { tile: 128, ..CannyParams::default() };
    let out = CannyPipeline::tiled(&pool).detect(&img, &params).unwrap();
    let spec = RunReport::from_run("tiled", img.len(), &out.times, None).to_sim_spec();
    let f_measured = 1.0 - spec.serial_fraction();
    println!(
        "measured parallel fraction f = {:.3} (serial = pad + hysteresis, paper's step 4)\n",
        f_measured
    );

    // Model curves (paper equation), f in {measured, 0.90, 0.99}.
    for f in [f_measured, 0.90, 0.99] {
        let mut table = Table::new(&["n", "symmetric", "asymmetric best", "best r"]);
        for p in curve(f, &[2, 4, 8, 16, 32, 64]) {
            table.row(&[
                p.n.to_string(),
                format!("{:.2}x", p.symmetric),
                format!("{:.2}x", p.asymmetric_best),
                p.best_r.to_string(),
            ]);
        }
        println!("Speedup(f = {f:.3}) — symmetric vs paper's asymmetric corollary:");
        table.print();
        println!();
    }

    // Validate: simulated speedups track the symmetric model.
    let t1 = simulate(&spec, 1).makespan_ns as f64;
    let mut table = Table::new(&["CPUs", "simulated", "model(f)", "error"]);
    for cpus in [2usize, 4, 8, 16] {
        let s = t1 / simulate(&spec, cpus).makespan_ns as f64;
        let m = speedup_symmetric(f_measured, cpus);
        table.row(&[
            cpus.to_string(),
            format!("{s:.2}x"),
            format!("{m:.2}x"),
            format!("{:+.1}%", 100.0 * (s - m) / m),
        ]);
    }
    println!("simulated vs Amdahl model at measured f:");
    table.print();

    let s8 = t1 / simulate(&spec, 8).makespan_ns as f64;
    println!("\nKarp-Flatt inverse fit at n=8: f = {:.3}", fit_parallel_fraction(s8, 8));
    let r = best_asymmetric_r(f_measured, 8);
    println!(
        "paper's asymmetric recommendation at n=8: r = {r} big-core -> {:.2}x vs symmetric {:.2}x",
        speedup_asymmetric(f_measured, 8, r),
        speedup_symmetric(f_measured, 8)
    );
}
