//! Figures 8 & 9 + §3.1 sample counts: total CPU usage over wall-clock
//! for the suboptimal (serial) vs optimal (parallel-patterns) CED,
//! rendered from measured stage/tile costs replayed on the simulated
//! 4-CPU topology (the paper's i3 testbed).
//!
//! Run: `cargo bench --bench fig8_9_cpu_usage`

use canny_par::bench::figures_dir;
use canny_par::canny::{CannyParams, CannyPipeline};
use canny_par::coordinator::RunReport;
use canny_par::image::synth::{generate, Scene};
use canny_par::profiler::UsageTrace;
use canny_par::scheduler::Pool;
use canny_par::simsched::simulate;

fn main() {
    let img = generate(Scene::Shapes { seed: 7 }, 1024, 1024);
    let params = CannyParams { tile: 128, ..CannyParams::default() };
    let pool = Pool::new(2).unwrap();

    // Measure real costs once per engine.
    let serial_out = CannyPipeline::serial().detect(&img, &params).unwrap();
    let tiled_out = CannyPipeline::tiled(&pool).detect(&img, &params).unwrap();
    let spec_sub = RunReport::from_run("serial", img.len(), &serial_out.times, None).to_sim_spec();
    let spec_opt = RunReport::from_run("tiled", img.len(), &tiled_out.times, None).to_sim_spec();

    let cpus = 4; // paper figure 8/9 ran the 4-CPU i3
    let period = 500_000; // 0.5 ms virtual sampling tick
    let sub = UsageTrace::from_sim(
        &simulate(&spec_sub, cpus),
        period,
        &format!("Fig 8 — suboptimal (serial) CED, {cpus} CPUs"),
    );
    let opt = UsageTrace::from_sim(
        &simulate(&spec_opt, cpus),
        period,
        &format!("Fig 9 — optimal (parallel patterns) CED, {cpus} CPUs"),
    );

    let dir = figures_dir();
    sub.write_csv(&dir.join("fig8_suboptimal_usage.csv")).unwrap();
    opt.write_csv(&dir.join("fig9_optimal_usage.csv")).unwrap();

    println!("{}", sub.ascii_total(72, 10));
    println!("{}", opt.ascii_total(72, 10));

    // §3.1 sample counts: busy samples per wall-clock tick. The paper's
    // profiler collected 8,992 (suboptimal) vs 34,884 (optimal) samples
    // on 4 CPUs — a 3.88x busy-sample-rate ratio (cap = 4.0).
    let rate_sub = sub.busy_samples() as f64 / sub.samples.len().max(1) as f64;
    let rate_opt = opt.busy_samples() as f64 / opt.samples.len().max(1) as f64;
    println!("mean total CPU usage: suboptimal {:.1}%  optimal {:.1}%", sub.mean_total_pct(), opt.mean_total_pct());
    println!(
        "busy-sample rate: suboptimal {:.2}/tick, optimal {:.2}/tick -> ratio {:.2}x",
        rate_sub,
        rate_opt,
        rate_opt / rate_sub.max(1e-9)
    );
    println!("paper §3.1:       8,992 vs 34,884 samples -> ratio 3.88x (4 CPUs)");
    println!("CSV written to {}", dir.display());
}
