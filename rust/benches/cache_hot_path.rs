//! Shared-artifact-cache hot path: re-threshold throughput with the
//! cache on vs off, over a hot (few distinct images, high reuse) and a
//! cold (every request distinct, zero reuse) working set.
//!
//! The hot sweep shows what the tier buys — a re-threshold that hits
//! skips Gaussian/Sobel/NMS and pays only hash + threshold +
//! hysteresis — and the cold sweep shows its overhead ceiling: every
//! request pays the content digest on top of the full front it runs
//! anyway.
//!
//! Run: `cargo bench --bench cache_hot_path`

use canny_par::bench::{bench, report, Table};
use canny_par::cache::{ArtifactCache, ArtifactKey, CacheConfig, CacheTier};
use canny_par::canny::{Artifact, CannyParams, StageKind};
use canny_par::coordinator::Detector;
use canny_par::image::synth::{generate, Scene};
use canny_par::image::ImageF32;

/// One re-threshold request against `img`: consult the cache when one
/// is given (miss fills), else always run the front.
fn rethreshold(
    det: &Detector,
    cache: Option<&ArtifactCache>,
    img: &ImageF32,
    params: &CannyParams,
) -> usize {
    let nm = match cache {
        Some(c) => {
            let key = ArtifactKey::suppressed(img);
            match c.get(&key, CacheTier::Serve) {
                Some(Artifact::Suppressed(nm)) => nm,
                _ => {
                    let front = det.plan().stop_after(StageKind::Nms);
                    let mut out = det.run_plan(&front, Some(img), det.params()).unwrap();
                    let nm = out.take_suppressed().unwrap();
                    c.offer(key, Artifact::Suppressed(nm.clone()), out.total_ns, CacheTier::Serve);
                    nm
                }
            }
        }
        None => {
            let front = det.plan().stop_after(StageKind::Nms);
            let mut out = det.run_plan(&front, Some(img), det.params()).unwrap();
            out.take_suppressed().unwrap()
        }
    };
    let plan = det.plan().from_suppressed(nm);
    let out = det.run_plan(&plan, None, params).unwrap();
    out.edges().unwrap().count_edges()
}

fn main() {
    let (w, h) = (512usize, 512);
    let requests = 24usize;
    let det = Detector::builder().workers(4).build().unwrap();
    let thresholds = [(0.03f32, 0.25f32), (0.05, 0.15), (0.08, 0.2)];

    // Hot: 4 distinct images cycled 6x each. Cold: 24 distinct images.
    let hot: Vec<ImageF32> =
        (0..requests).map(|k| generate(Scene::Shapes { seed: (k % 4) as u64 }, w, h)).collect();
    let cold: Vec<ImageF32> =
        (0..requests).map(|k| generate(Scene::Shapes { seed: 1000 + k as u64 }, w, h)).collect();

    let mut table =
        Table::new(&["working set", "cache", "median/run", "Mpix/s", "hit rate"]);
    let mpix = (requests * w * h) as f64 / 1e6;

    for (set_name, images) in [("hot (4 distinct)", &hot), ("cold (all distinct)", &cold)] {
        for cached in [false, true] {
            // The cache persists across iterations (steady-state tier),
            // like a long-running server's.
            let cache = ArtifactCache::new(CacheConfig::default());
            let summary = bench(1, 5, || {
                let mut edges = 0usize;
                for (k, img) in images.iter().enumerate() {
                    let (lo, hi) = thresholds[k % thresholds.len()];
                    let params = CannyParams { lo, hi, ..CannyParams::default() };
                    edges += rethreshold(
                        &det,
                        cached.then_some(&cache),
                        img,
                        &params,
                    );
                }
                edges
            });
            let snap = cache.snapshot();
            let hit_rate = if snap.lookups() == 0 {
                0.0
            } else {
                snap.hits() as f64 / snap.lookups() as f64
            };
            report(
                &format!("cache_hot_path/{}{}", if cached { "on/" } else { "off/" }, set_name),
                &summary,
            );
            table.row(&[
                set_name.to_string(),
                if cached { "on" } else { "off" }.to_string(),
                summary.human_median(),
                format!("{:.2}", mpix / (summary.median_ns as f64 / 1e9)),
                if cached { format!("{:.0}%", 100.0 * hit_rate) } else { "-".to_string() },
            ]);
        }
    }
    println!("\nShared artifact cache — {requests} re-threshold requests of {w}x{h}:");
    table.print();
    println!("hot-set speedup = cache-on Mpix/s over cache-off on the hot rows;");
    println!("cold rows bound the content-digest overhead (cache on, 0% reuse).");
}
