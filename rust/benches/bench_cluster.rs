//! Cluster-tier scaling bench: the same synthetic trace through real
//! `cannyd worker` process fleets of 1, 2 and 4, reported as Mpix/s
//! and latency percentiles per fleet size — and written to
//! `BENCH_cluster.json` so CI can archive the numbers as a non-gating
//! artifact (process spawn + loopback framing make these even noisier
//! than the serve bench; regressions are read from the artifact
//! history, never from a red build).
//!
//! Run: `cargo bench --bench bench_cluster`
//! Output: `BENCH_cluster.json` (override with `BENCH_CLUSTER_JSON=path`).

use std::collections::BTreeMap;

use canny_par::bench::Table;
use canny_par::cluster::{run_cluster, ClusterOptions, WORKER_EXE_ENV};
use canny_par::config::RunConfig;
use canny_par::service::Trace;
use canny_par::util::json::Json;
use canny_par::util::timer::human_ns;

/// The artifact schema CI archives: exactly these keys at the top
/// level, and exactly the fleet keys in every `fleets` entry. The
/// assertions below fail the bench when a key drifts.
const REQUIRED_BENCH_KEYS: [&str; 5] = ["bench", "width", "height", "requests", "fleets"];
const REQUIRED_FLEET_KEYS: [&str; 8] = [
    "workers",
    "completed",
    "requeued",
    "restarts",
    "makespan_ns",
    "mpix_per_s",
    "p50_ns",
    "p99_ns",
];

/// Nearest-rank percentile over an already-sorted slice.
fn pct(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

fn main() {
    // The bench harness is not `cannyd`; point worker respawns at the
    // binary cargo built alongside this bench.
    std::env::set_var(WORKER_EXE_ENV, env!("CARGO_BIN_EXE_cannyd"));

    let (w, h) = (256usize, 256);
    let n = 32usize;
    let mut trace = Trace::synthetic(n, 7, 2_000.0);
    for r in &mut trace.requests {
        r.width = w;
        r.height = h;
    }

    let mut t = Table::new(&["workers", "completed", "makespan", "Mpix/s", "p50", "p99"]);
    let mut fleets = Vec::new();
    for workers in [1usize, 2, 4] {
        let mut cfg = RunConfig::default();
        cfg.set("workers", &workers.to_string()).expect("set workers");
        let opts = ClusterOptions::from_config(&cfg);
        let label = format!("bench_cluster[workers={workers}]");
        let out = run_cluster(&label, &trace, &opts).expect("cluster run");
        let report = &out.report;

        let wall_s = report.makespan_ns as f64 / 1e9;
        let mpix = (report.completed as usize * w * h) as f64 / 1e6;
        let mpix_per_s = if wall_s > 0.0 { mpix / wall_s } else { 0.0 };
        let mut sorted = report.latencies_ns.clone();
        sorted.sort_unstable();
        let (p50, p99) = (pct(&sorted, 50.0), pct(&sorted, 99.0));

        t.row(&[
            workers.to_string(),
            report.completed.to_string(),
            human_ns(report.makespan_ns),
            format!("{mpix_per_s:.2}"),
            human_ns(p50),
            human_ns(p99),
        ]);

        let num = Json::Num;
        let mut f = BTreeMap::new();
        f.insert("workers".into(), num(workers as f64));
        f.insert("completed".into(), num(report.completed as f64));
        f.insert("requeued".into(), num(report.requeued as f64));
        f.insert("restarts".into(), num(report.restarts as f64));
        f.insert("makespan_ns".into(), num(report.makespan_ns as f64));
        f.insert("mpix_per_s".into(), num(mpix_per_s));
        f.insert("p50_ns".into(), num(p50 as f64));
        f.insert("p99_ns".into(), num(p99 as f64));
        for key in REQUIRED_FLEET_KEYS {
            assert!(f.contains_key(key), "fleet entry is missing required key `{key}`");
        }
        assert_eq!(f.len(), REQUIRED_FLEET_KEYS.len(), "fleet entry emits undeclared keys");
        fleets.push(Json::Obj(f));
    }

    println!("cluster tier, {n} requests at {w}x{h}, process fleets of 1/2/4:");
    t.print();

    let num = Json::Num;
    let mut m = BTreeMap::new();
    m.insert("bench".into(), Json::Str("cluster".into()));
    m.insert("width".into(), num(w as f64));
    m.insert("height".into(), num(h as f64));
    m.insert("requests".into(), num(n as f64));
    m.insert("fleets".into(), Json::Arr(fleets));
    for key in REQUIRED_BENCH_KEYS {
        assert!(m.contains_key(key), "bench artifact is missing required key `{key}`");
    }
    assert_eq!(m.len(), REQUIRED_BENCH_KEYS.len(), "bench artifact emits undeclared keys");
    let path =
        std::env::var("BENCH_CLUSTER_JSON").unwrap_or_else(|_| "BENCH_cluster.json".into());
    std::fs::write(&path, Json::Obj(m).dump() + "\n").expect("write bench artifact");
    println!("wrote {path}");
}
