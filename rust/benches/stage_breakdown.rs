//! §2.2.1 steps 1-4: per-stage cost breakdown, serial vs pattern-
//! parallel, quantifying where the parallel patterns pay off and how
//! big the hysteresis serial elision really is.
//!
//! Run: `cargo bench --bench stage_breakdown`

use canny_par::bench::{bench, report, Table};
use canny_par::canny::{consts, gaussian, hysteresis, nms, sobel, threshold};
use canny_par::canny::{CannyParams, CannyPipeline};
use canny_par::image::synth::{generate, Scene};
use canny_par::scheduler::Pool;
use canny_par::util::timer::human_ns;

fn main() {
    let img = generate(Scene::Shapes { seed: 7 }, 1024, 1024);
    let params = CannyParams::default();
    let padded = img.pad_replicate(consts::HALO);

    // Individual stage micro-benches (serial, whole image).
    let g = gaussian::gaussian(&padded);
    let (mag, dir) = sobel::sobel(&g);
    let nm = nms::nms(&mag, &dir);
    let cls = threshold::threshold(&nm, params.lo, params.hi);

    let s_gauss = bench(1, 5, || gaussian::gaussian(&padded));
    let s_sobel = bench(1, 5, || sobel::sobel(&g));
    let s_nms = bench(1, 5, || nms::nms(&mag, &dir));
    let s_thresh = bench(1, 5, || threshold::threshold(&nm, params.lo, params.hi));
    let s_hyst = bench(1, 5, || hysteresis::hysteresis_serial(&cls));
    report("stage/gaussian(serial)", &s_gauss);
    report("stage/sobel(serial)", &s_sobel);
    report("stage/nms(serial)", &s_nms);
    report("stage/threshold(serial)", &s_thresh);
    report("stage/hysteresis(serial)", &s_hyst);

    let pool = Pool::new(4).unwrap();
    let p_hyst = bench(1, 5, || hysteresis::hysteresis_parallel(&pool, &cls));
    report("stage/hysteresis(parallel-ext)", &p_hyst);

    // Whole-pipeline stage shares, serial vs patterns engine.
    let serial = CannyPipeline::serial().detect(&img, &params).unwrap();
    let patterns = CannyPipeline::patterns(&pool).detect(&img, &params).unwrap();
    let mut table = Table::new(&["stage", "serial", "patterns(4w)", "share of serial total"]);
    let rows = [
        ("pad", serial.times.pad_ns, patterns.times.pad_ns),
        ("gaussian", serial.times.gaussian_ns, patterns.times.gaussian_ns),
        ("sobel", serial.times.sobel_ns, patterns.times.sobel_ns),
        ("nms", serial.times.nms_ns, patterns.times.nms_ns),
        ("threshold", serial.times.threshold_ns, patterns.times.threshold_ns),
        ("hysteresis", serial.times.hysteresis_ns, patterns.times.hysteresis_ns),
    ];
    for (name, s, p) in rows {
        table.row(&[
            name.to_string(),
            human_ns(s),
            human_ns(p),
            format!("{:.1}%", 100.0 * s as f64 / serial.times.total_ns as f64),
        ]);
    }
    println!("\n§2.2.1 stage breakdown (1024x1024):");
    table.print();
    println!(
        "\nhysteresis (the paper's forced-serial step 4) = {:.1}% of serial total;",
        100.0 * serial.times.hysteresis_ns as f64 / serial.times.total_ns as f64
    );
    println!("parallel-extension hysteresis median {} vs serial {}.",
        human_ns(p_hyst.median_ns), human_ns(s_hyst.median_ns));
}
