//! Figure 7 + headline throughput: run every engine end-to-end on the
//! demo scene, write the edge maps (the paper's application-run figure)
//! and report Mpix/s per engine.
//!
//! Run: `cargo bench --bench fig7_e2e`

use std::path::Path;

use canny_par::bench::{bench, figures_dir, report, Table};
use canny_par::canny::{CannyParams, CannyPipeline};
use canny_par::coordinator::RunReport;
use canny_par::image::pgm;
use canny_par::image::synth::{generate, Scene};
use canny_par::runtime::{Manifest, XlaEngine};
use canny_par::scheduler::Pool;

fn main() {
    let (w, h) = (1024, 768);
    let img = generate(Scene::Shapes { seed: 7 }, w, h);
    let params = CannyParams::default();
    let pool = Pool::new(4).unwrap();
    let dir = figures_dir();
    pgm::write_pgm(&dir.join("fig7_input.pgm"), &img.to_u8()).unwrap();

    let xla = Manifest::load(&Manifest::default_dir())
        .and_then(|m| XlaEngine::from_manifest(&m, "t128", 4))
        .ok();
    if xla.is_none() {
        println!("note: no artifacts/ — skipping xla engine (run `make artifacts`)");
    }

    let mut table = Table::new(&["engine", "median", "Mpix/s", "edges", "speedup vs serial"]);
    let mut serial_ns = 0u64;
    let engines: Vec<(&str, CannyPipeline)> = {
        let mut v = vec![
            ("serial", CannyPipeline::serial()),
            ("patterns", CannyPipeline::patterns(&pool)),
            ("tiled", CannyPipeline::tiled(&pool)),
        ];
        if let Some(x) = xla.as_ref() {
            v.push(("xla", CannyPipeline::xla(&pool, x)));
        }
        v
    };

    for (name, pipeline) in engines {
        let summary = bench(2, 8, || pipeline.detect(&img, &params).unwrap());
        let out = pipeline.detect(&img, &params).unwrap();
        pgm::write_pgm(
            &dir.join(format!("fig7_edges_{name}.pgm")),
            &out.edges.to_image(),
        )
        .unwrap();
        if name == "serial" {
            serial_ns = summary.median_ns;
        }
        let rpt = RunReport::from_run(name, img.len(), &out.times, None);
        report(&format!("fig7_e2e/{name}"), &summary);
        table.row(&[
            name.to_string(),
            summary.human_median(),
            format!("{:.2}", (img.len() as f64 / 1e6) / (summary.median_ns as f64 / 1e9)),
            format!("{}", out.edges.count_edges()),
            format!("{:.2}x", serial_ns as f64 / summary.median_ns as f64),
        ]);
        let _ = rpt;
    }
    println!("\nFigure 7 — parallel CED application run ({w}x{h} shapes scene):");
    table.print();
    println!("edge maps written to {}", dir.display());
    println!("note: wall-clock speedups on this {}-CPU host are not the paper's scaling", canny_par::coordinator::topology::available_cpus());
    println!("      claim — see table1_scaling (virtual topology) for the reproduction.");
    let _ = Path::new("");
}
