//! Serving-tier throughput bench: a wall-clock `serve` run with real
//! detection, reported as Mpix/s and latency percentiles — and written
//! to `BENCH_serve.json` so CI can archive the numbers as a non-gating
//! artifact (regressions show up in the artifact history, not as a red
//! build on a noisy shared runner).
//!
//! Run: `cargo bench --bench bench_serve`
//! Output: `BENCH_serve.json` (override with `BENCH_SERVE_JSON=path`).

use std::collections::BTreeMap;

use canny_par::bench::Table;

/// The artifact schema CI archives: exactly these keys, no drift. The
/// assertion below fails the bench (not just the archive diff) when an
/// emitted key is renamed, dropped, or added without updating the list.
const REQUIRED_BENCH_KEYS: [&str; 15] = [
    "bench",
    "clock",
    "lanes",
    "workers_per_lane",
    "width",
    "height",
    "requests",
    "completed",
    "rejected",
    "makespan_ns",
    "mpix_per_s",
    "p50_ns",
    "p95_ns",
    "p99_ns",
    "edge_pixels",
];
use canny_par::config::RunConfig;
use canny_par::service::{serve, ClockMode, ServeOptions, Trace};
use canny_par::util::json::Json;
use canny_par::util::timer::human_ns;

fn main() {
    let (w, h) = (256usize, 256);
    let n = 48usize;
    let mut opts = ServeOptions::from_config(&RunConfig::default());
    opts.clock = ClockMode::Wall;
    opts.execute = true;
    opts.lanes = 2;
    opts.workers_per_lane = 2;
    opts.max_batch = 4;
    opts.batch_window_ns = 200_000;

    // 2 kHz arrivals: fast enough to keep both lanes busy, slow enough
    // that the queue never overflows on a laptop-class host.
    let mut trace = Trace::synthetic(n, 7, 2_000.0);
    for r in &mut trace.requests {
        r.width = w;
        r.height = h;
    }

    let report = serve("bench_serve", &trace, &opts).expect("serve run");
    let wall_s = report.makespan_ns as f64 / 1e9;
    let mpix = (report.completed as usize * w * h) as f64 / 1e6;
    let mpix_per_s = if wall_s > 0.0 { mpix / wall_s } else { 0.0 };

    let mut t = Table::new(&["requests", "completed", "makespan", "Mpix/s", "p50", "p99"]);
    t.row(&[
        n.to_string(),
        report.completed.to_string(),
        human_ns(report.makespan_ns),
        format!("{mpix_per_s:.2}"),
        human_ns(report.latency.p50_ns),
        human_ns(report.latency.p99_ns),
    ]);
    println!("serve tier, wall clock, {} lanes x {} workers:", opts.lanes, opts.workers_per_lane);
    t.print();

    // The machine-readable artifact CI uploads.
    let mut m = BTreeMap::new();
    let num = Json::Num;
    m.insert("bench".into(), Json::Str("serve".into()));
    m.insert("clock".into(), Json::Str("wall".into()));
    m.insert("lanes".into(), num(opts.lanes as f64));
    m.insert("workers_per_lane".into(), num(opts.workers_per_lane as f64));
    m.insert("width".into(), num(w as f64));
    m.insert("height".into(), num(h as f64));
    m.insert("requests".into(), num(n as f64));
    m.insert("completed".into(), num(report.completed as f64));
    m.insert("rejected".into(), num(report.rejected() as f64));
    m.insert("makespan_ns".into(), num(report.makespan_ns as f64));
    m.insert("mpix_per_s".into(), num(mpix_per_s));
    m.insert("p50_ns".into(), num(report.latency.p50_ns as f64));
    m.insert("p95_ns".into(), num(report.latency.p95_ns as f64));
    m.insert("p99_ns".into(), num(report.latency.p99_ns as f64));
    m.insert("edge_pixels".into(), num(report.edge_pixels as f64));
    for key in REQUIRED_BENCH_KEYS {
        assert!(m.contains_key(key), "bench artifact is missing required key `{key}`");
    }
    assert_eq!(m.len(), REQUIRED_BENCH_KEYS.len(), "bench artifact emits undeclared keys");
    let path = std::env::var("BENCH_SERVE_JSON").unwrap_or_else(|_| "BENCH_serve.json".into());
    std::fs::write(&path, Json::Obj(m).dump() + "\n").expect("write bench artifact");
    println!("wrote {path}");
}
