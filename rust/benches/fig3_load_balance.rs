//! Figure 3 — "even distribution of load on cores": per-core busy time
//! of the tile front under work stealing, simulated 4/8 CPUs, plus the
//! live pool's per-worker histogram. Metric: coefficient of variation.
//!
//! Run: `cargo bench --bench fig3_load_balance`

use canny_par::bench::Table;
use canny_par::canny::{CannyParams, CannyPipeline};
use canny_par::coordinator::RunReport;
use canny_par::image::synth::{generate, Scene};
use canny_par::metrics::coefficient_of_variation;
use canny_par::scheduler::Pool;
use canny_par::simsched::simulate;
use canny_par::util::timer::human_ns;

fn main() {
    let img = generate(Scene::Shapes { seed: 7 }, 1024, 1024);
    let params = CannyParams { tile: 128, ..CannyParams::default() };
    let pool = Pool::new(4).unwrap();
    pool.stats().reset();
    let out = CannyPipeline::tiled(&pool).detect(&img, &params).unwrap();

    // Live pool histogram (real threads on this host).
    let busy = pool.stats().busy_ns_per_worker();
    println!("live pool (4 workers) per-worker busy time:");
    for (i, b) in busy.iter().enumerate() {
        let bar = "#".repeat((b * 40 / busy.iter().max().copied().unwrap_or(1).max(1)) as usize);
        println!("  worker {i}: {:>10}  {bar}", human_ns(*b));
    }
    println!(
        "  tasks {} steals {} CoV {:.3}\n",
        pool.stats().total_tasks(),
        pool.stats().total_steals(),
        coefficient_of_variation(&busy.iter().map(|&b| b as f64).collect::<Vec<_>>())
    );

    // Simulated Table-1 topologies from the measured tile costs.
    let spec = RunReport::from_run("tiled", img.len(), &out.times, None).to_sim_spec();
    let mut table = Table::new(&["CPUs", "per-core busy (ms)", "CoV", "steals"]);
    for cpus in [4usize, 8] {
        let sim = simulate(&spec, cpus);
        let ms: Vec<String> =
            sim.busy_ns.iter().map(|&b| format!("{:.1}", b as f64 / 1e6)).collect();
        table.row(&[
            cpus.to_string(),
            ms.join(" "),
            format!(
                "{:.3}",
                coefficient_of_variation(&sim.busy_ns.iter().map(|&b| b as f64).collect::<Vec<_>>())
            ),
            sim.steals.iter().sum::<u64>().to_string(),
        ]);
    }
    println!("Figure 3 — load distribution under work stealing (simulated):");
    table.print();
    println!("\npaper claim: \"even distribution of work across all cores\" — CoV ~ 0.");
}
