//! Figures 9b/10 (suboptimal per-core) and 11/12 (optimal per-core) on
//! the simulated 4-CPU (i3) and 8-CPU (i7) topologies: uneven/idle
//! cores for the serial build, even and high utilization for the
//! parallel-patterns build (the work-stealing load-balance claim).
//!
//! Run: `cargo bench --bench fig9_12_per_core`

use canny_par::bench::figures_dir;
use canny_par::canny::{CannyParams, CannyPipeline};
use canny_par::coordinator::RunReport;
use canny_par::image::synth::{generate, Scene};
use canny_par::metrics::coefficient_of_variation;
use canny_par::profiler::UsageTrace;
use canny_par::scheduler::Pool;
use canny_par::simsched::simulate;

fn main() {
    let img = generate(Scene::Shapes { seed: 7 }, 1024, 1024);
    let params = CannyParams { tile: 128, ..CannyParams::default() };
    let pool = Pool::new(2).unwrap();

    let serial_out = CannyPipeline::serial().detect(&img, &params).unwrap();
    let tiled_out = CannyPipeline::tiled(&pool).detect(&img, &params).unwrap();
    let spec_sub = RunReport::from_run("serial", img.len(), &serial_out.times, None).to_sim_spec();
    let spec_opt = RunReport::from_run("tiled", img.len(), &tiled_out.times, None).to_sim_spec();

    let dir = figures_dir();
    let period = 500_000u64;
    let cases = [
        ("fig9b", "suboptimal", &spec_sub, 4usize),
        ("fig10", "suboptimal", &spec_sub, 8),
        ("fig11", "optimal", &spec_opt, 4),
        ("fig12", "optimal", &spec_opt, 8),
    ];
    for (fig, kind, spec, cpus) in cases {
        let sim = simulate(spec, cpus);
        let trace = UsageTrace::from_sim(
            &sim,
            period,
            &format!("{fig} — {kind} CED per-core usage ({cpus} CPUs)"),
        );
        trace.write_csv(&dir.join(format!("{fig}_{kind}_{cpus}cpu_per_core.csv"))).unwrap();
        println!("{}", trace.ascii_per_core(72, 4));
        let util = sim.per_core_utilization();
        let cov = coefficient_of_variation(&util);
        println!(
            "{fig}: per-core utilization {:?} (CoV {:.3})\n",
            util.iter().map(|u| format!("{:.0}%", 100.0 * u)).collect::<Vec<_>>(),
            cov
        );
    }
    println!("paper shape check: suboptimal = core0-only (others idle);");
    println!("                   optimal   = all cores high & even (low CoV).");
    println!("CSV written to {}", dir.display());
}
