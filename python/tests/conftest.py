"""Shared pytest fixtures: deterministic RNG + hypothesis profile tuned
for interpret-mode Pallas (slow per-example, so fewer examples)."""

import os
import sys

import numpy as np
import pytest
from hypothesis import HealthCheck, settings

# Allow `pytest python/tests` from the repo root as well as `cd python`.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

settings.register_profile(
    "pallas",
    max_examples=12,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
settings.load_profile("pallas")


@pytest.fixture
def rng():
    return np.random.default_rng(0xC0FFEE)
