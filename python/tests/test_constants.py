"""Guard the cross-language numeric contract (python <-> rust)."""

import math

import numpy as np

from compile.kernels.constants import GAUSS5, HALO, TAN22, TAN67

# rust/src/canny/consts.rs hardcodes these very literals; if this test
# moves, the rust side must move with it.
RUST_GAUSS5 = (0.11020945757627487, 0.23691201210021973, 0.3057570457458496)


def test_gauss5_normalized():
    assert abs(sum(GAUSS5) - 1.0) < 1e-6


def test_gauss5_symmetric():
    assert GAUSS5[0] == GAUSS5[4]
    assert GAUSS5[1] == GAUSS5[3]


def test_gauss5_values_match_rust_contract():
    assert np.float32(GAUSS5[0]) == np.float32(RUST_GAUSS5[0])
    assert np.float32(GAUSS5[1]) == np.float32(RUST_GAUSS5[1])
    assert np.float32(GAUSS5[2]) == np.float32(RUST_GAUSS5[2])


def test_gauss5_formula():
    raw = [math.exp(-(k * k) / (2 * 1.4**2)) for k in (-2, -1, 0, 1, 2)]
    s = sum(raw)
    for k in range(5):
        assert abs(GAUSS5[k] - raw[k] / s) < 1e-7


def test_tan_thresholds():
    assert abs(TAN22 - math.tan(math.radians(22.5))) < 1e-7
    assert abs(TAN67 - math.tan(math.radians(67.5))) < 1e-7


def test_halo_budget():
    # gaussian(2) + sobel(1) + nms(1)
    assert HALO == 4
