"""CORE correctness signal: every Pallas kernel == its pure-jnp oracle,
exactly (interpret mode executes the same jnp ops, so we demand bitwise
or near-bitwise agreement)."""

import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile.kernels import gauss_cols, gauss_rows, gaussian, nms, sobel, threshold
from compile.kernels import ref

SHAPES = [(16, 16), (24, 40), (136, 136), (33, 17)]


def _img(rng, shape):
    return jnp.asarray(rng.random(shape, dtype=np.float32))


@pytest.mark.parametrize("shape", SHAPES)
def test_gauss_rows_matches_ref(rng, shape):
    x = _img(rng, shape)
    assert_allclose(np.asarray(gauss_rows(x)), np.asarray(ref.gauss_rows_ref(x)), rtol=1e-6)


@pytest.mark.parametrize("shape", SHAPES)
def test_gauss_cols_matches_ref(rng, shape):
    x = _img(rng, shape)
    assert_allclose(np.asarray(gauss_cols(x)), np.asarray(ref.gauss_cols_ref(x)), rtol=1e-6)


@pytest.mark.parametrize("shape", SHAPES)
def test_gaussian_matches_ref(rng, shape):
    x = _img(rng, shape)
    assert_allclose(np.asarray(gaussian(x)), np.asarray(ref.gaussian_ref(x)), rtol=1e-6)


@pytest.mark.parametrize("shape", SHAPES)
def test_sobel_matches_ref(rng, shape):
    x = _img(rng, shape)
    mag, dirc = sobel(x)
    rmag, rdir = ref.sobel_ref(x)
    assert_allclose(np.asarray(mag), np.asarray(rmag), rtol=1e-6, atol=1e-7)
    np.testing.assert_array_equal(np.asarray(dirc), np.asarray(rdir))


@pytest.mark.parametrize("shape", SHAPES)
def test_nms_matches_ref(rng, shape):
    x = _img(rng, shape)
    mag, dirc = ref.sobel_ref(x)
    assert_allclose(
        np.asarray(nms(mag, dirc)), np.asarray(ref.nms_ref(mag, dirc)), rtol=1e-6, atol=1e-7
    )


@pytest.mark.parametrize("shape", SHAPES)
def test_threshold_matches_ref(rng, shape):
    m = _img(rng, shape) * 4.0
    lo = jnp.asarray([0.4], dtype=jnp.float32)
    hi = jnp.asarray([1.2], dtype=jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(threshold(m, lo, hi)), np.asarray(ref.threshold_ref(m, 0.4, 1.2))
    )


def test_gaussian_preserves_constant(rng):
    # Normalized taps: blurring a constant image is the identity.
    x = jnp.full((32, 32), 3.25, dtype=jnp.float32)
    out = gaussian(x)
    assert_allclose(np.asarray(out), np.full((28, 28), 3.25, dtype=np.float32), rtol=1e-6)


def test_sobel_flat_image_zero_everything(rng):
    x = jnp.full((20, 20), 0.5, dtype=jnp.float32)
    mag, dirc = sobel(x)
    np.testing.assert_array_equal(np.asarray(mag), np.zeros((18, 18), np.float32))
    # gx = gy = 0 -> bin 0 by convention (ady <= t*adx with both 0).
    np.testing.assert_array_equal(np.asarray(dirc), np.zeros((18, 18), np.float32))


def test_sobel_vertical_edge_is_bin0(rng):
    # A vertical step edge has a horizontal gradient -> E/W comparisons.
    x = jnp.concatenate(
        [jnp.zeros((16, 8), jnp.float32), jnp.ones((16, 8), jnp.float32)], axis=1
    )
    mag, dirc = sobel(x)
    col = np.asarray(mag)[:, 6]  # the edge column in the valid region
    assert (col > 0).all()
    assert (np.asarray(dirc)[:, 6] == 0.0).all()


def test_sobel_horizontal_edge_is_bin2(rng):
    x = jnp.concatenate(
        [jnp.zeros((8, 16), jnp.float32), jnp.ones((8, 16), jnp.float32)], axis=0
    )
    mag, dirc = sobel(x)
    row = np.asarray(mag)[6, :]
    assert (row > 0).all()
    assert (np.asarray(dirc)[6, :] == 2.0).all()


def test_nms_thins_ramp_to_single_line(rng):
    # Gradient magnitude peaked on one column must survive only there.
    mag = np.zeros((10, 10), np.float32)
    mag[:, 4] = 2.0
    mag[:, 3] = 1.0
    mag[:, 5] = 1.0
    dirc = np.zeros((10, 10), np.float32)  # bin 0: compare E/W
    out = np.asarray(nms(jnp.asarray(mag), jnp.asarray(dirc)))
    assert (out[:, 3] == 2.0).all()  # column 4 in full coords -> 3 in interior
    assert (out[:, 2] == 0.0).all()
    assert (out[:, 4] == 0.0).all()


def test_threshold_classes_exhaustive():
    m = jnp.asarray([[0.0, 0.39999, 0.4, 1.19999, 1.2, 5.0]], dtype=jnp.float32)
    lo = jnp.asarray([0.4], dtype=jnp.float32)
    hi = jnp.asarray([1.2], dtype=jnp.float32)
    out = np.asarray(threshold(m, lo, hi))[0]
    np.testing.assert_array_equal(out, [0.0, 0.0, 1.0, 1.0, 2.0, 2.0])
