"""L2 model tests: fused front == oracle composition, shape/halo algebra,
determinism across jit re-traces."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import ref

LO, HI = 0.08, 0.2


def _padded(rng, core_h, core_w):
    return jnp.asarray(
        rng.random((core_h + 2 * model.HALO, core_w + 2 * model.HALO), dtype=np.float32)
    )


def _scal(v):
    return jnp.asarray([v], dtype=jnp.float32)


@pytest.mark.parametrize("core", [(16, 16), (32, 24), (64, 64)])
def test_canny_front_matches_ref(rng, core):
    x = _padded(rng, *core)
    cls, nm = model.canny_front(x, _scal(LO), _scal(HI))
    rcls, rnm = ref.canny_front_ref(x, np.float32(LO), np.float32(HI))
    assert cls.shape == core and nm.shape == core
    assert_allclose(np.asarray(nm), np.asarray(rnm), rtol=1e-5, atol=1e-7)
    np.testing.assert_array_equal(np.asarray(cls), np.asarray(rcls))


def test_halo_algebra(rng):
    """Stages shrink padded (H+8,W+8) -> -4 -> -2 -> -2 -> (H,W)."""
    x = _padded(rng, 20, 28)
    g = model.gaussian_stage(x)
    assert g.shape == (24, 32)
    mag, dirc = model.sobel_stage(g)
    assert mag.shape == dirc.shape == (22, 30)
    nm = model.nms_stage(mag, dirc)
    assert nm.shape == (20, 28)
    cls = model.threshold_stage(nm, _scal(LO), _scal(HI))
    assert cls.shape == (20, 28)


def test_stagewise_equals_fused(rng):
    x = _padded(rng, 24, 24)
    g = model.gaussian_stage(x)
    mag, dirc = model.sobel_stage(g)
    nm = model.nms_stage(mag, dirc)
    cls = model.threshold_stage(nm, _scal(LO), _scal(HI))
    fcls, fnm = model.canny_front(x, _scal(LO), _scal(HI))
    np.testing.assert_array_equal(np.asarray(cls), np.asarray(fcls))
    np.testing.assert_array_equal(np.asarray(nm), np.asarray(fnm))


def test_jit_deterministic(rng):
    x = _padded(rng, 16, 16)
    f = jax.jit(model.canny_front)
    a1, b1 = f(x, _scal(LO), _scal(HI))
    a2, b2 = f(x, _scal(LO), _scal(HI))
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    np.testing.assert_array_equal(np.asarray(b1), np.asarray(b2))


def test_tiling_consistency(rng):
    """Running the front on two overlapping padded tiles gives the same
    interior as running it on the full image — the invariant the L3 tile
    scheduler relies on."""
    core, halo = 16, model.HALO
    full = jnp.asarray(rng.random((2 * core + 2 * halo, core + 2 * halo), dtype=np.float32))
    cls_full, _ = model.canny_front(full, _scal(LO), _scal(HI))
    top = full[: core + 2 * halo, :]
    bot = full[core:, :]
    cls_top, _ = model.canny_front(top, _scal(LO), _scal(HI))
    cls_bot, _ = model.canny_front(bot, _scal(LO), _scal(HI))
    np.testing.assert_array_equal(np.asarray(cls_full)[:core], np.asarray(cls_top))
    np.testing.assert_array_equal(np.asarray(cls_full)[core:], np.asarray(cls_bot))


def test_class_map_values(rng):
    x = _padded(rng, 16, 16)
    cls, _ = model.canny_front(x, _scal(LO), _scal(HI))
    vals = np.unique(np.asarray(cls))
    assert set(vals.tolist()) <= {0.0, 1.0, 2.0}
