"""Gridded (BlockSpec) Gaussian row pass == oracle, plus VMEM budget."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given
from hypothesis import strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.kernels.gaussian_blocked import (
    BLOCK_ROWS,
    gauss_rows_blocked,
    vmem_bytes_per_block,
)


def test_matches_ref_on_block_multiple(rng):
    x = jnp.asarray(rng.random((64, 96), dtype=np.float32))
    assert_allclose(
        np.asarray(gauss_rows_blocked(x)), np.asarray(ref.gauss_rows_ref(x)), rtol=1e-6
    )


def test_matches_plain_kernel(rng):
    from compile.kernels import gauss_rows

    x = jnp.asarray(rng.random((136, 136), dtype=np.float32))
    np.testing.assert_array_equal(
        np.asarray(gauss_rows_blocked(x)), np.asarray(gauss_rows(x))
    )


def test_fallback_on_odd_height(rng):
    x = jnp.asarray(rng.random((BLOCK_ROWS * 2 + 3, 40), dtype=np.float32))
    assert_allclose(
        np.asarray(gauss_rows_blocked(x)), np.asarray(ref.gauss_rows_ref(x)), rtol=1e-6
    )


@given(
    hb=st.integers(min_value=1, max_value=8),
    w=st.integers(min_value=16, max_value=160),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_blocked_prop(hb, w, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.random((hb * BLOCK_ROWS, w), dtype=np.float32))
    assert_allclose(
        np.asarray(gauss_rows_blocked(x)), np.asarray(ref.gauss_rows_ref(x)), rtol=1e-5
    )


def test_vmem_budget_for_aot_shapes():
    # One slab of the largest AOT tile must sit far below a TPU core's
    # ~16 MiB VMEM (leave >100x headroom for double buffering).
    for padded_w in (72, 136, 264):
        assert vmem_bytes_per_block(padded_w) < 16 * 1024 * 1024 / 100
