"""Hypothesis sweep: kernel == oracle over arbitrary shapes and value
ranges (the mandated shape/dtype property sweep for the L1 kernels)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st
from numpy.testing import assert_allclose

from compile.kernels import gaussian, nms, sobel, threshold
from compile.kernels import ref

dims = st.integers(min_value=9, max_value=72)
seeds = st.integers(min_value=0, max_value=2**32 - 1)
scales = st.sampled_from([1.0, 255.0, 1e-3])


def _img(seed, h, w, scale):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.random((h, w), dtype=np.float32) * np.float32(scale))


@given(h=dims, w=dims, seed=seeds, scale=scales)
def test_gaussian_prop(h, w, seed, scale):
    x = _img(seed, h, w, scale)
    assert_allclose(
        np.asarray(gaussian(x)), np.asarray(ref.gaussian_ref(x)), rtol=1e-5, atol=1e-6 * scale
    )


@given(h=dims, w=dims, seed=seeds, scale=scales)
def test_sobel_prop(h, w, seed, scale):
    x = _img(seed, h, w, scale)
    mag, dirc = sobel(x)
    rmag, rdir = ref.sobel_ref(x)
    assert_allclose(np.asarray(mag), np.asarray(rmag), rtol=1e-5, atol=1e-6 * scale)
    np.testing.assert_array_equal(np.asarray(dirc), np.asarray(rdir))


@given(h=dims, w=dims, seed=seeds)
def test_nms_prop(h, w, seed):
    x = _img(seed, h, w, 1.0)
    mag, dirc = ref.sobel_ref(x)
    assert_allclose(
        np.asarray(nms(mag, dirc)), np.asarray(ref.nms_ref(mag, dirc)), rtol=1e-6, atol=1e-7
    )


@given(h=dims, w=dims, seed=seeds, lo=st.floats(0.01, 0.5), hi=st.floats(0.5, 2.0))
def test_threshold_prop(h, w, seed, lo, hi):
    m = _img(seed, h, w, 2.0)
    lo_a = jnp.asarray([lo], dtype=jnp.float32)
    hi_a = jnp.asarray([hi], dtype=jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(threshold(m, lo_a, hi_a)),
        np.asarray(ref.threshold_ref(m, np.float32(lo), np.float32(hi))),
    )


@given(h=dims, w=dims, seed=seeds)
@settings(max_examples=8)
def test_nms_output_sparser_than_input(h, w, seed):
    """NMS never increases the number of non-zero pixels (it suppresses)."""
    x = _img(seed, h, w, 1.0)
    mag, dirc = ref.sobel_ref(x)
    out = np.asarray(nms(mag, dirc))
    inner = np.asarray(mag)[1:-1, 1:-1]
    assert (out > 0).sum() <= (inner > 0).sum()
    # And every surviving value equals its input magnitude.
    mask = out > 0
    np.testing.assert_array_equal(out[mask], inner[mask])
