"""AOT pipeline tests: artifacts exist, are valid HLO text, manifest is
consistent with the shape algebra the rust loader assumes."""

import json
import os

import pytest

from compile import aot


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build(str(out), verbose=False)
    return str(out), manifest


def test_manifest_structure(built):
    out, manifest = built
    assert manifest["format"] == 1
    assert manifest["halo"] == 4
    names = [t["name"] for t in manifest["tiles"]]
    assert names == ["t64", "t128", "t256"]
    assert len(manifest["constants"]["gauss5"]) == 5


def test_all_tiles_have_fused_front(built):
    _, manifest = built
    for tile in manifest["tiles"]:
        assert "canny_front" in tile["entries"]
        core_h, core_w = tile["core"]
        e = tile["entries"]["canny_front"]
        assert e["inputs"] == [[core_h + 8, core_w + 8], [1], [1]]
        assert e["outputs"] == [[core_h, core_w], [core_h, core_w]]


def test_stage_entries_only_on_stage_tile(built):
    _, manifest = built
    for tile in manifest["tiles"]:
        expected = 5 if tile["name"] == aot.STAGE_TILE else 1
        assert len(tile["entries"]) == expected


def test_hlo_text_is_parseable_entry(built):
    out, manifest = built
    for tile in manifest["tiles"]:
        for e in tile["entries"].values():
            path = os.path.join(out, e["file"])
            assert os.path.exists(path)
            text = open(path).read()
            assert "ENTRY" in text and "ROOT" in text
            # interpret-mode pallas must NOT leave custom-calls behind
            assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


def test_manifest_on_disk_matches_return(built):
    out, manifest = built
    disk = json.load(open(os.path.join(out, "manifest.json")))
    assert disk == json.loads(json.dumps(manifest))


def test_rebuild_is_stable(built, tmp_path):
    """Lowering twice produces identical HLO (deterministic AOT)."""
    out, manifest = built
    again = aot.build(str(tmp_path), verbose=False)
    for t1, t2 in zip(manifest["tiles"], again["tiles"]):
        for name in t1["entries"]:
            assert t1["entries"][name]["sha256"] == t2["entries"][name]["sha256"]
