"""AOT bridge: lower every L2 entry point to HLO *text* + manifest.json.

HLO text (NOT `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the rust `xla` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Run once at build time (`make artifacts`); the rust binary consumes
artifacts/ and never touches python again.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model
from .kernels.constants import GAUSS5, HALO, TAN22, TAN67

# Tile configurations exported for the rust coordinator. "core" is the
# interior the tile produces; inputs to canny_front are core + 2*HALO.
TILE_CONFIGS = [
    {"name": "t64", "core": [64, 64]},
    {"name": "t128", "core": [128, 128]},
    {"name": "t256", "core": [256, 256]},
]

# Stage artifacts are emitted for this tile only (stage-pipeline mode and
# the per-stage benches run at one canonical size).
STAGE_TILE = "t128"


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _f32(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jax.numpy.float32)


def _lower_entries(core_h, core_w, stages):
    """Yield (entry_name, lowered, input_shapes, output_shapes)."""
    ph, pw = core_h + 2 * HALO, core_w + 2 * HALO
    scal = _f32((1,))

    yield (
        "canny_front",
        jax.jit(model.canny_front).lower(_f32((ph, pw)), scal, scal),
        [[ph, pw], [1], [1]],
        [[core_h, core_w], [core_h, core_w]],
    )
    if not stages:
        return
    # Stage shapes chain: padded -> -4 -> -2 -> -2 (matching HALO budget).
    g_h, g_w = ph - 4, pw - 4
    s_h, s_w = g_h - 2, g_w - 2
    yield (
        "gaussian_stage",
        jax.jit(model.gaussian_stage).lower(_f32((ph, pw))),
        [[ph, pw]],
        [[g_h, g_w]],
    )
    yield (
        "sobel_stage",
        jax.jit(model.sobel_stage).lower(_f32((g_h, g_w))),
        [[g_h, g_w]],
        [[s_h, s_w], [s_h, s_w]],
    )
    yield (
        "nms_stage",
        jax.jit(model.nms_stage).lower(_f32((s_h, s_w)), _f32((s_h, s_w))),
        [[s_h, s_w], [s_h, s_w]],
        [[core_h, core_w]],
    )
    yield (
        "threshold_stage",
        jax.jit(model.threshold_stage).lower(_f32((core_h, core_w)), scal, scal),
        [[core_h, core_w], [1], [1]],
        [[core_h, core_w]],
    )


def build(out_dir: str, verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "format": 1,
        "halo": HALO,
        "constants": {"gauss5": list(GAUSS5), "tan22": TAN22, "tan67": TAN67},
        "tiles": [],
    }
    for cfg in TILE_CONFIGS:
        core_h, core_w = cfg["core"]
        tile_entry = {"name": cfg["name"], "core": cfg["core"], "entries": {}}
        stages = cfg["name"] == STAGE_TILE
        for name, lowered, in_shapes, out_shapes in _lower_entries(core_h, core_w, stages):
            text = to_hlo_text(lowered)
            fname = f"{name}_{cfg['name']}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            tile_entry["entries"][name] = {
                "file": fname,
                "inputs": in_shapes,
                "outputs": out_shapes,
                "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
            }
            if verbose:
                print(f"  wrote {fname}: {len(text)} chars, in={in_shapes} out={out_shapes}")
        manifest["tiles"].append(tile_entry)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    if verbose:
        print(f"  wrote manifest.json ({len(manifest['tiles'])} tile configs)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()
    build(args.out_dir, verbose=not args.quiet)


if __name__ == "__main__":
    main()
