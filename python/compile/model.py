"""L2: the JAX compute graph for the Canny front-end, calling the L1
Pallas kernels so everything lowers into one HLO module per entry point.

Entry points (all shapes fixed at lowering time by aot.py):

  canny_front(x, lo, hi)   (H+8, W+8), (1,), (1,) -> (class (H,W), nms (H,W))
      The fused per-tile front-end the Rust hot path executes.
  gaussian_stage(x)        (H, W) -> (H-4, W-4)
  sobel_stage(g)           (H, W) -> (mag, dirc) each (H-2, W-2)
  nms_stage(mag, dirc)     (H, W) x2 -> (H-2, W-2)
  threshold_stage(m, lo, hi)  (H, W) -> (H, W)
      Individual stages for the stage-pipeline execution mode and the
      per-stage benches (paper §2.2.1 steps 1-4a).

Hysteresis *connectivity* (step 4b) is deliberately absent: the paper
keeps it serial on the CPU side; it lives in rust/src/canny/hysteresis.rs.
"""

from .kernels import gauss_cols, gauss_rows, nms, sobel, threshold

# Total one-side halo consumed by gaussian (2) + sobel (1) + nms (1).
HALO = 4


def gaussian_stage(x):
    """Separable Gaussian blur stage. (H, W) -> (H-4, W-4)."""
    return gauss_cols(gauss_rows(x))


def sobel_stage(g):
    """Sobel gradient stage. (H, W) -> ((H-2, W-2) mag, (H-2, W-2) dirc)."""
    return sobel(g)


def nms_stage(mag, dirc):
    """Non-maximum suppression stage. (H, W) x2 -> (H-2, W-2)."""
    return nms(mag, dirc)


def threshold_stage(m, lo, hi):
    """Double-threshold stage. (H, W) -> (H, W) class map."""
    return threshold(m, lo, hi)


def canny_front(x, lo, hi):
    """Fused Canny front-end over one padded tile.

    x: (H+8, W+8) f32 padded tile; lo, hi: shape-(1,) f32 thresholds.
    Returns (class_map (H, W), nms_magnitude (H, W)).
    """
    g = gaussian_stage(x)
    mag, dirc = sobel_stage(g)
    nm = nms_stage(mag, dirc)
    return threshold_stage(nm, lo, hi), nm
