"""L1 Pallas kernel, gridded variant: the row-pass Gaussian with an
explicit HBM<->VMEM schedule via BlockSpec.

The single-block kernels in gaussian.py treat one L3 tile as one VMEM
block (the Rust coordinator owns the outer schedule). This variant
shows the other point in the design space — the kernel itself tiles a
larger image over a 1-D grid of row blocks, the way a CUDA
implementation would use threadblocks (DESIGN.md §Hardware-Adaptation):

  * grid = ceil(H / BLOCK_ROWS)
  * input BlockSpec: (BLOCK_ROWS, W) slabs, index_map i -> (i, 0)
  * output BlockSpec: same slabs of the (H, W-4) result

The row pass has no vertical halo, so row-slab blocking needs no
overlap — the natural decomposition, and the reason the separable
formulation maps well onto both threadblocks and VMEM slabs. The
vertical pass would need a +4-row halo per slab; the production path
keeps whole-tile blocks instead (tile + halo already fits VMEM:
136*136*4 B = 74 KiB << 16 MiB).

Used by the VMEM-budget analysis in DESIGN.md and tested against the
same oracle as the plain kernel. Not wired into the AOT artifacts.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .constants import GAUSS5

BLOCK_ROWS = 8


def _gauss_rows_block_kernel(x_ref, o_ref):
    x = x_ref[...]
    w_out = o_ref.shape[1]
    acc = jnp.float32(GAUSS5[0]) * x[:, 0:w_out]
    for k in range(1, 5):
        acc = acc + jnp.float32(GAUSS5[k]) * x[:, k : k + w_out]
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=())
def gauss_rows_blocked(x):
    """Horizontal 5-tap Gaussian with a row-slab grid.

    (H, W) -> (H, W-4); H must be a multiple of BLOCK_ROWS (the AOT
    shapes are; arbitrary H falls back to the single-block kernel).
    """
    h, w = x.shape
    if h % BLOCK_ROWS != 0:
        from .gaussian import gauss_rows

        return gauss_rows(x)
    grid = h // BLOCK_ROWS
    return pl.pallas_call(
        _gauss_rows_block_kernel,
        out_shape=jax.ShapeDtypeStruct((h, w - 4), x.dtype),
        grid=(grid,),
        in_specs=[pl.BlockSpec((BLOCK_ROWS, w), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BLOCK_ROWS, w - 4), lambda i: (i, 0)),
        interpret=True,
    )(x)


def vmem_bytes_per_block(w: int, dtype_bytes: int = 4) -> int:
    """VMEM working set of one grid step: input slab + output slab.

    The DESIGN.md §Perf budget check: must stay well under ~16 MiB/core
    on a real TPU for double-buffering headroom.
    """
    return BLOCK_ROWS * w * dtype_bytes + BLOCK_ROWS * (w - 4) * dtype_bytes
