"""Canonical numeric constants shared by the Pallas kernels, the pure-jnp
oracle (ref.py) and the native Rust implementation (rust/src/canny/).

These are THE definitions: the Rust side hardcodes the same decimal literals
(see rust/src/canny/consts.rs); test_constants.py guards the contract.
"""

import math

# --- 5-tap Gaussian, sigma = 1.4 (the classic Canny choice) ---------------
GAUSS_SIGMA = 1.4


def _gauss5_f32():
    raw = [math.exp(-(k * k) / (2.0 * GAUSS_SIGMA * GAUSS_SIGMA)) for k in (-2, -1, 0, 1, 2)]
    s = sum(raw)
    # Round through f32 so every layer sees bit-identical taps.
    import numpy as np

    return tuple(float(np.float32(v / s)) for v in raw)


GAUSS5 = _gauss5_f32()

# --- Sobel direction quantization thresholds ------------------------------
# bin 0 (E/W neighbours)   : |gy| <= TAN22 * |gx|
# bin 2 (N/S neighbours)   : |gy| >  TAN67 * |gx|
# bin 1 (NW/SE neighbours) : otherwise, gx * gy >= 0
# bin 3 (NE/SW neighbours) : otherwise, gx * gy <  0
TAN22 = 0.41421356  # tan(22.5 deg), f32-rounded
TAN67 = 2.41421356  # tan(67.5 deg), f32-rounded

# --- Stage halo budget -----------------------------------------------------
# gaussian 5x5 separable -> radius 2; sobel 3x3 -> radius 1; nms -> radius 1
HALO = 4

# --- Hysteresis classes (produced by threshold kernel, consumed by rust) ---
CLASS_NONE = 0.0
CLASS_WEAK = 1.0
CLASS_STRONG = 2.0
