"""L1: Pallas kernels for the Canny front-end hot-spots.

Each kernel has a pure-jnp oracle in ref.py; pytest (python/tests/)
asserts allclose between the two across hypothesis-generated shapes.
"""

from .constants import CLASS_NONE, CLASS_STRONG, CLASS_WEAK, GAUSS5, HALO, TAN22, TAN67
from .gaussian import gauss_cols, gauss_rows, gaussian
from .nms import nms
from .sobel import sobel
from .threshold import threshold

__all__ = [
    "CLASS_NONE",
    "CLASS_STRONG",
    "CLASS_WEAK",
    "GAUSS5",
    "HALO",
    "TAN22",
    "TAN67",
    "gauss_cols",
    "gauss_rows",
    "gaussian",
    "nms",
    "sobel",
    "threshold",
]
