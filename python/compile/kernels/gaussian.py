"""L1 Pallas kernels: separable 5-tap Gaussian blur (sigma = 1.4).

The paper parallelizes the Gaussian noise filter with Cilk parallel
patterns (map over pixels). On the TPU-shaped stack the same insight
becomes: keep the tile resident in VMEM and express the filter as two
1-D passes (rows then cols) so the inner loop is a pure VPU
multiply-accumulate over contiguous lanes. One L3 tile == one Pallas
block: the HBM<->VMEM schedule (which tile when) is owned by the Rust
coordinator, so each kernel here runs grid-less on a single block.

interpret=True everywhere: the CPU PJRT client cannot run Mosaic
custom-calls; interpret mode lowers to plain HLO (see DESIGN.md).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .constants import GAUSS5


def _gauss_rows_kernel(x_ref, o_ref):
    x = x_ref[...]
    w_out = o_ref.shape[1]
    acc = jnp.float32(GAUSS5[0]) * x[:, 0:w_out]
    for k in range(1, 5):
        acc = acc + jnp.float32(GAUSS5[k]) * x[:, k : k + w_out]
    o_ref[...] = acc


def _gauss_cols_kernel(x_ref, o_ref):
    x = x_ref[...]
    h_out = o_ref.shape[0]
    acc = jnp.float32(GAUSS5[0]) * x[0:h_out, :]
    for k in range(1, 5):
        acc = acc + jnp.float32(GAUSS5[k]) * x[k : k + h_out, :]
    o_ref[...] = acc


def gauss_rows(x):
    """Horizontal 5-tap Gaussian pass. (H, W) -> (H, W-4)."""
    h, w = x.shape
    return pl.pallas_call(
        _gauss_rows_kernel,
        out_shape=jax.ShapeDtypeStruct((h, w - 4), x.dtype),
        interpret=True,
    )(x)


def gauss_cols(x):
    """Vertical 5-tap Gaussian pass. (H, W) -> (H-4, W)."""
    h, w = x.shape
    return pl.pallas_call(
        _gauss_cols_kernel,
        out_shape=jax.ShapeDtypeStruct((h - 4, w), x.dtype),
        interpret=True,
    )(x)


def gaussian(x):
    """Separable 5x5 Gaussian blur. (H, W) -> (H-4, W-4)."""
    return gauss_cols(gauss_rows(x))
