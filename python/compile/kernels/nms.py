"""L1 Pallas kernel: non-maximum suppression stencil.

Paper step 3: keep a pixel only if its gradient magnitude is a local
maximum along the quantized gradient direction ("low pass filter for
unwanted pixels"). Branch-free select over the four direction bins so
the stencil stays fully vectorized; ties keep (>= both neighbours),
which makes the output deterministic and identical to ref.py and rust.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _nms_kernel(mag_ref, dir_ref, o_ref):
    mag = mag_ref[...]
    h, w = mag.shape
    h_out, w_out = o_ref.shape
    m = mag[1 : h - 1, 1 : w - 1]
    d = dir_ref[1 : h - 1, 1 : w - 1]

    def nb(di, dj):
        return mag[1 + di : h - 1 + di, 1 + dj : w - 1 + dj]

    n1 = jnp.where(
        d == 0.0, nb(0, -1), jnp.where(d == 2.0, nb(-1, 0), jnp.where(d == 1.0, nb(-1, -1), nb(-1, 1)))
    )
    n2 = jnp.where(
        d == 0.0, nb(0, 1), jnp.where(d == 2.0, nb(1, 0), jnp.where(d == 1.0, nb(1, 1), nb(1, -1)))
    )
    keep = (m >= n1) & (m >= n2)
    o_ref[...] = jnp.where(keep, m, 0.0).astype(mag.dtype)


def nms(mag, dirc):
    """Non-maximum suppression. (H, W)x2 -> (H-2, W-2)."""
    h, w = mag.shape
    return pl.pallas_call(
        _nms_kernel,
        out_shape=jax.ShapeDtypeStruct((h - 2, w - 2), mag.dtype),
        interpret=True,
    )(mag, dirc)
