"""L1 Pallas kernel: double-threshold classification.

Paper step 4 splits into (a) the per-pixel double threshold — trivially
parallel, done here — and (b) the connectivity walk, which the paper
deliberately leaves serial (Amdahl) and which lives in
rust/src/canny/hysteresis.rs on the L3 side.

Class map contract: 0 = suppressed, 1 = weak (keep iff connected to a
strong pixel), 2 = strong.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _threshold_kernel(m_ref, lo_ref, hi_ref, o_ref):
    m = m_ref[...]
    lo = lo_ref[0]
    hi = hi_ref[0]
    o_ref[...] = jnp.where(m >= hi, 2.0, jnp.where(m >= lo, 1.0, 0.0)).astype(m.dtype)


def threshold(m, lo, hi):
    """Double threshold. m: (H, W); lo, hi: shape-(1,) f32 -> (H, W) classes."""
    return pl.pallas_call(
        _threshold_kernel,
        out_shape=jax.ShapeDtypeStruct(m.shape, m.dtype),
        interpret=True,
    )(m, lo, hi)
