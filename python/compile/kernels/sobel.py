"""L1 Pallas kernel: fused 3x3 Sobel gradient + magnitude + direction
quantization.

The paper's step 2 computes (Gx, Gy), gradient strength and direction in
parallel. Here the whole step is ONE fused kernel: nine shifted reads of
the VMEM-resident tile, two MAC chains for gx/gy, rsqrt-free magnitude
and a branch-free direction quantization (tangent comparisons instead of
atan2 — deterministic and far cheaper on the VPU; see
DESIGN.md §Hardware-Adaptation).

Direction encoding (contract with nms + rust): 0 = E/W, 1 = NW/SE,
2 = N/S, 3 = NE/SW.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .constants import TAN22, TAN67


def _sobel_kernel(x_ref, mag_ref, dir_ref):
    x = x_ref[...]
    h_out, w_out = mag_ref.shape

    def p(di, dj):
        return x[di : di + h_out, dj : dj + w_out]

    gx = (p(0, 2) - p(0, 0)) + 2.0 * (p(1, 2) - p(1, 0)) + (p(2, 2) - p(2, 0))
    gy = (p(0, 0) + 2.0 * p(0, 1) + p(0, 2)) - (p(2, 0) + 2.0 * p(2, 1) + p(2, 2))
    mag_ref[...] = jnp.sqrt(gx * gx + gy * gy)
    adx = jnp.abs(gx)
    ady = jnp.abs(gy)
    b0 = ady <= jnp.float32(TAN22) * adx
    b2 = ady > jnp.float32(TAN67) * adx
    same = gx * gy >= 0.0
    dir_ref[...] = jnp.where(b0, 0.0, jnp.where(b2, 2.0, jnp.where(same, 1.0, 3.0))).astype(
        x.dtype
    )


def sobel(x):
    """Fused Sobel. (H, W) -> (mag, dirc), each (H-2, W-2)."""
    h, w = x.shape
    out = jax.ShapeDtypeStruct((h - 2, w - 2), x.dtype)
    return pl.pallas_call(
        _sobel_kernel,
        out_shape=(out, out),
        interpret=True,
    )(x)
