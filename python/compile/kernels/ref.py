"""Pure-jnp oracle for every Pallas kernel (L1) and the fused front (L2).

This module is the numerical ground truth the Pallas kernels are tested
against (python/tests/) and the contract the native Rust path mirrors.
No pallas imports here — plain jax.numpy only.
"""

import jax.numpy as jnp

from .constants import GAUSS5, TAN22, TAN67


def gauss_rows_ref(x):
    """Horizontal 5-tap Gaussian. (H, W) -> (H, W-4)."""
    h, w = x.shape
    acc = jnp.zeros((h, w - 4), dtype=x.dtype)
    for k in range(5):
        acc = acc + jnp.float32(GAUSS5[k]) * x[:, k : k + w - 4]
    return acc


def gauss_cols_ref(x):
    """Vertical 5-tap Gaussian. (H, W) -> (H-4, W)."""
    h, w = x.shape
    acc = jnp.zeros((h - 4, w), dtype=x.dtype)
    for k in range(5):
        acc = acc + jnp.float32(GAUSS5[k]) * x[k : k + h - 4, :]
    return acc


def gaussian_ref(x):
    """Separable 5x5 Gaussian blur. (H, W) -> (H-4, W-4)."""
    return gauss_cols_ref(gauss_rows_ref(x))


def sobel_ref(x):
    """3x3 Sobel gradient magnitude + quantized direction.

    (H, W) -> (mag, dirc) each (H-2, W-2); dirc in {0., 1., 2., 3.}:
      0 -> compare E/W, 1 -> NW/SE, 2 -> N/S, 3 -> NE/SW.
    """
    h, w = x.shape

    def p(di, dj):
        return x[di : di + h - 2, dj : dj + w - 2]

    gx = (p(0, 2) - p(0, 0)) + 2.0 * (p(1, 2) - p(1, 0)) + (p(2, 2) - p(2, 0))
    gy = (p(0, 0) + 2.0 * p(0, 1) + p(0, 2)) - (p(2, 0) + 2.0 * p(2, 1) + p(2, 2))
    mag = jnp.sqrt(gx * gx + gy * gy)
    adx = jnp.abs(gx)
    ady = jnp.abs(gy)
    b0 = ady <= jnp.float32(TAN22) * adx
    b2 = ady > jnp.float32(TAN67) * adx
    same = gx * gy >= 0.0
    dirc = jnp.where(b0, 0.0, jnp.where(b2, 2.0, jnp.where(same, 1.0, 3.0)))
    return mag, dirc.astype(x.dtype)


def nms_ref(mag, dirc):
    """Non-maximum suppression. (H, W)x2 -> (H-2, W-2).

    Keeps the centre magnitude iff it is >= both neighbours along the
    quantized gradient direction (ties keep: deterministic + matches rust).
    """
    h, w = mag.shape
    m = mag[1 : h - 1, 1 : w - 1]
    d = dirc[1 : h - 1, 1 : w - 1]

    def nb(di, dj):
        return mag[1 + di : h - 1 + di, 1 + dj : w - 1 + dj]

    n1 = jnp.where(
        d == 0.0, nb(0, -1), jnp.where(d == 2.0, nb(-1, 0), jnp.where(d == 1.0, nb(-1, -1), nb(-1, 1)))
    )
    n2 = jnp.where(
        d == 0.0, nb(0, 1), jnp.where(d == 2.0, nb(1, 0), jnp.where(d == 1.0, nb(1, 1), nb(1, -1)))
    )
    keep = (m >= n1) & (m >= n2)
    return jnp.where(keep, m, 0.0).astype(mag.dtype)


def threshold_ref(m, lo, hi):
    """Double threshold -> class map {0: none, 1: weak, 2: strong}."""
    return jnp.where(m >= hi, 2.0, jnp.where(m >= lo, 1.0, 0.0)).astype(m.dtype)


def canny_front_ref(x, lo, hi):
    """Fused Canny front-end (everything before hysteresis connectivity).

    (H+8, W+8) padded tile -> (class (H, W), nms-magnitude (H, W)).
    """
    g = gaussian_ref(x)
    mag, dirc = sobel_ref(g)
    nm = nms_ref(mag, dirc)
    return threshold_ref(nm, lo, hi), nm
