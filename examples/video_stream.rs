//! Real-time video edge detection through the pipeline pattern
//! (generate → Canny front → hysteresis+collect), the workload class
//! the paper's FPGA comparator [18] reports 240 fps on.
//!
//! Run: `cargo run --release --example video_stream`

use canny_par::canny::{CannyParams, CannyPipeline};
use canny_par::image::synth::{generate, Scene};
use canny_par::image::ImageF32;
use canny_par::patterns::pipeline::pipeline3;
use canny_par::scheduler::Pool;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let pool = Pool::new(4).unwrap();
    let params = CannyParams { tile: 128, ..CannyParams::default() };
    let (w, h) = (640, 360);
    let frames = 90usize;

    // Stage 1: frame source (synthetic camera: moving shapes).
    // Stage 2: Canny front (tiled patterns on the pool).
    // Stage 3: hysteresis + feature summary.
    let t0 = Instant::now();
    let results = pipeline3(
        0..frames,
        4, // bounded queues: at most 4 frames in flight per stage
        |k| generate(Scene::Video { seed: 3, frame: k }, w, h),
        |frame: ImageF32| {
            let out = CannyPipeline::tiled(&pool).detect(&frame, &params).unwrap();
            out
        },
        |out| out.edges.count_edges(),
    );
    let wall = t0.elapsed();
    let fps = frames as f64 / wall.as_secs_f64();

    let min = results.iter().min().unwrap();
    let max = results.iter().max().unwrap();
    println!(
        "{frames} frames @ {w}x{h} in {:.2} s -> {:.1} fps ({:.2} Mpix/s)",
        wall.as_secs_f64(),
        fps,
        (frames * w * h) as f64 / 1e6 / wall.as_secs_f64()
    );
    println!("edge pixels per frame: min {min}, max {max} (objects moving across frames)");
    println!("\n(reference point: the paper's FPGA comparator [18] reports 240 fps");
    println!(" on 1 Mpix images on a Spartan-3E; this is a {}-CPU host)",
        canny_par::coordinator::topology::available_cpus());
    Ok(())
}
