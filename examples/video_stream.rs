//! Real-time video edge detection through the stream tier — the
//! workload class the paper's FPGA comparator [18] reports 240 fps on.
//!
//! A `FrameSource` feeds the pipeline-parallel decode → delta-gated
//! front → finish executor: moving tiles recompute, static tiles reuse
//! the previous frame's cached suppressed-magnitude artifact (exact at
//! the default threshold 0), and the whole chain keeps a bounded
//! window of frames in flight.
//!
//! Run: `cargo run --release --example video_stream`

use canny_par::canny::{CannyParams, Engine};
use canny_par::coordinator::Detector;
use canny_par::stream::{run_stream, FrameSource, StreamOptions};

fn main() -> anyhow::Result<()> {
    // The stream tier reads detection params from StreamOptions (the
    // gated front tiles itself; the detector's engine/pool drive the
    // finish stages).
    let params = CannyParams::default();
    let det = Detector::builder().engine(Engine::TiledPatterns).workers(4).build()?;
    let (w, h) = (640, 360);
    let frames = 90usize;
    let source = FrameSource::synthetic(3, frames, w, h);
    let opts = StreamOptions {
        inflight: 4, // bounded queues: at most 4 frames in flight per stage
        params,
        ..StreamOptions::default()
    };

    let out = run_stream("video_stream", &source, &det, &opts)?;
    let r = &out.report;
    println!(
        "{} frames @ {w}x{h} in {:.2} s -> {:.1} fps ({:.2} Mpix/s)",
        r.frames_emitted,
        r.wall_ns as f64 / 1e9,
        r.fps(),
        r.mpix_per_s()
    );
    let min = out.frames.iter().map(|f| f.edge_pixels).min().unwrap_or(0);
    let max = out.frames.iter().map(|f| f.edge_pixels).max().unwrap_or(0);
    println!("edge pixels per frame: min {min}, max {max} (objects moving across frames)");
    println!(
        "delta gate: {:.0}% tile reuse across {} gated frames ({} tiles recomputed)",
        100.0 * r.gate.hit_rate(),
        r.gate.frames_gated,
        r.gate.tiles_dirty
    );
    println!("\n(reference point: the paper's FPGA comparator [18] reports 240 fps");
    println!(
        " on 1 Mpix images on a Spartan-3E; this is a {}-CPU host)",
        canny_par::coordinator::topology::available_cpus()
    );
    Ok(())
}
