//! END-TO-END DRIVER: exercises the whole system on a real workload and
//! regenerates every paper figure in one run — the validation artifact
//! recorded in EXPERIMENTS.md.
//!
//! Flow (all three layers composing):
//!   1. GCP shell: a batch of mixed-scene captures arrives.
//!   2. GCP kernel: topology detected, plan chosen (engine/tile/workers).
//!   3. GCP core: the batch runs on the work-stealing pool — native
//!      tiled engine AND the PJRT engine (JAX/Pallas AOT artifacts).
//!   4. Profiling: measured stage/tile costs replayed on the paper's
//!      i3 (4 CPU) and i7 (8 CPU) topologies -> Figures 3, 8-12,
//!      Table 1 scaling, Amdahl analysis, §3.1 sample counts.
//!
//! Run: `cargo run --release --example profile_figures`

use canny_par::amdahl;
use canny_par::bench::{figures_dir, Table};
use canny_par::canny::{CannyParams, CannyPipeline};
use canny_par::coordinator::batch::BatchJob;
use canny_par::coordinator::planner::Workload;
use canny_par::coordinator::{BatchServer, CpuTopology, Detector, Planner, RunReport};
use canny_par::image::pgm;
use canny_par::image::synth::{generate, Scene};
use canny_par::metrics::coefficient_of_variation;
use canny_par::profiler::UsageTrace;
use canny_par::runtime::Manifest;
use canny_par::simsched::simulate;

fn main() -> anyhow::Result<()> {
    println!("=== canny-par end-to-end driver ===\n");
    let dir = figures_dir();

    // ---- 1+2: shell & kernel (plan) --------------------------------
    let host = CpuTopology::detect();
    let artifacts = Manifest::load(&Manifest::default_dir()).ok();
    println!("host: {}", host.name);
    let planner = Planner::new(host.clone()).with_xla(artifacts.is_some());
    let work = Workload { image_w: 1024, image_h: 1024, batch: 1 };
    let plan = planner.plan(work, &CannyParams::default());
    println!("plan: engine={} workers={} tile={} ({})\n",
        plan.engine.name(), plan.workers, plan.params.tile, plan.rationale);

    // ---- 3: the real workload through the full stack ----------------
    let img = generate(Scene::Shapes { seed: 7 }, 1024, 1024);
    let params = CannyParams { tile: 128, ..CannyParams::default() };

    // Native engines (use >=2 workers even on a 1-CPU host: correctness
    // is host-independent; scaling figures come from the simulator).
    let det = Detector::builder()
        .engine(canny_par::canny::Engine::TiledPatterns)
        .workers(host.logical_cpus.max(2))
        .params(params)
        .build()?;
    let serial_out = CannyPipeline::serial().detect(&img, &params)?;
    det.pool_stats().reset();
    let tiled_out = det.detect_full(&img, &params)?;
    let tiled_report =
        RunReport::from_run("tiled", img.len(), &tiled_out.times, Some(&det.pool_stats()));
    println!("serial : {}", RunReport::from_run("serial", img.len(), &serial_out.times, None).summary());
    println!("tiled  : {}", tiled_report.summary());
    assert_eq!(serial_out.edges.diff_count(&tiled_out.edges), 0, "determinism violated!");

    // PJRT path (L1/L2 artifacts through L3), if built.
    if artifacts.is_some() {
        let xdet = Detector::builder()
            .engine(canny_par::canny::Engine::PatternsXla)
            .workers(host.logical_cpus.max(2))
            .params(params)
            .build()?;
        let xout = xdet.detect_full(&img, &params)?;
        let xrep = RunReport::from_run("xla", img.len(), &xout.times, Some(&xdet.pool_stats()));
        println!("xla    : {}", xrep.summary());
        let diff = xout.edges.diff_count(&serial_out.edges);
        println!(
            "xla vs serial edge map: {diff}/{} pixels differ ({:.4}%) [f32 tie boundaries]",
            img.len(),
            100.0 * diff as f64 / img.len() as f64
        );
        assert!((diff as f64) < 0.002 * img.len() as f64);
        pgm::write_pgm(&dir.join("e2e_edges_xla.pgm"), &xout.edges.to_image())?;
    } else {
        println!("xla    : skipped (run `make artifacts`)");
    }
    pgm::write_pgm(&dir.join("e2e_input.pgm"), &img.to_u8())?;
    pgm::write_pgm(&dir.join("e2e_edges.pgm"), &tiled_out.edges.to_image())?;

    // Batch throughput (the farm front door).
    let jobs = (0..16).map(|k| BatchJob {
        id: k,
        image: generate(Scene::Shapes { seed: k as u64 }, 512, 384),
    });
    let batch = BatchServer::new(&det).run(jobs, &params)?;
    println!(
        "batch  : 16 images -> {:.2} img/s, {:.2} Mpix/s, {} stalls\n",
        batch.images_per_s(),
        batch.mpix_per_s(),
        batch.farm.stalls
    );

    // ---- 4: figures from measured costs on Table-1 topologies -------
    let spec_sub = RunReport::from_run("s", img.len(), &serial_out.times, None).to_sim_spec();
    let spec_opt = tiled_report.to_sim_spec();
    let period = 500_000u64;

    println!("--- Figures 8/9 (total CPU usage, 4 CPUs) ---");
    let sub4 = UsageTrace::from_sim(&simulate(&spec_sub, 4), period, "Fig 8 suboptimal 4 CPUs");
    let opt4 = UsageTrace::from_sim(&simulate(&spec_opt, 4), period, "Fig 9 optimal 4 CPUs");
    println!("{}", sub4.ascii_total(72, 8));
    println!("{}", opt4.ascii_total(72, 8));
    sub4.write_csv(&dir.join("fig8_suboptimal_usage.csv"))?;
    opt4.write_csv(&dir.join("fig9_optimal_usage.csv"))?;
    println!(
        "mean usage: suboptimal {:.0}% vs optimal {:.0}% | busy-sample rate ratio {:.2}x (paper 3.88x)\n",
        sub4.mean_total_pct(),
        opt4.mean_total_pct(),
        (opt4.busy_samples() as f64 / opt4.samples.len() as f64)
            / (sub4.busy_samples() as f64 / sub4.samples.len() as f64),
    );

    println!("--- Figures 9b-12 (per-core) + Figure 3 (load balance) ---");
    let mut t = Table::new(&["figure", "config", "per-core util", "CoV"]);
    for (fig, spec, cpus) in [
        ("9b", &spec_sub, 4usize),
        ("10", &spec_sub, 8),
        ("11", &spec_opt, 4),
        ("12", &spec_opt, 8),
    ] {
        let sim = simulate(spec, cpus);
        let trace = UsageTrace::from_sim(&sim, period, &format!("fig{fig}"));
        trace.write_csv(&dir.join(format!("fig{fig}_per_core.csv")))?;
        let util = sim.per_core_utilization();
        t.row(&[
            format!("fig{fig}"),
            format!("{} CPUs", cpus),
            util.iter().map(|u| format!("{:.0}%", u * 100.0)).collect::<Vec<_>>().join(" "),
            format!("{:.3}", coefficient_of_variation(&util)),
        ]);
    }
    t.print();

    println!("\n--- Table 1 scaling + Amdahl ---");
    let t1 = simulate(&spec_opt, 1).makespan_ns as f64;
    let f = 1.0 - spec_opt.serial_fraction();
    let mut t2 = Table::new(&["CPUs", "speedup", "efficiency", "Amdahl bound"]);
    for cpus in [2usize, 4, 8, 32, 64] {
        let s = t1 / simulate(&spec_opt, cpus).makespan_ns as f64;
        t2.row(&[
            cpus.to_string(),
            format!("{s:.2}x"),
            format!("{:.0}%", 100.0 * s / cpus as f64),
            format!("{:.2}x", amdahl::speedup_symmetric(f, cpus)),
        ]);
    }
    t2.print();
    println!("\nmeasured parallel fraction f = {f:.3}; asymmetric best r at n=8: {}",
        amdahl::best_asymmetric_r(f, 8));

    println!("\nall figures written to {}", dir.display());
    println!("=== end-to-end driver complete ===");
    Ok(())
}
