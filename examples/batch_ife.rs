//! Batch IFE: the paper's motivating workload — a large quantity of
//! images streamed through the farm pattern with bounded backpressure
//! (image-feature-extraction servers on "the INTERNET", §2.1 [8][9]).
//!
//! Run: `cargo run --release --example batch_ife`

use canny_par::canny::CannyParams;
use canny_par::coordinator::batch::BatchJob;
use canny_par::coordinator::{BatchServer, Detector};
use canny_par::image::synth::{generate, Scene};

fn main() -> anyhow::Result<()> {
    let det = Detector::builder().workers(4).build()?;
    let params = CannyParams::default();
    let n = 48;
    let (w, h) = (512, 384);

    // A mixed corpus: photos, documents, remote-sensing captures.
    let jobs: Vec<BatchJob> = (0..n)
        .map(|k| {
            let scene = match k % 3 {
                0 => Scene::Shapes { seed: k as u64 },
                1 => Scene::Text { seed: k as u64 },
                _ => Scene::RemoteSensing { seed: k as u64, noise: 0.05 },
            };
            BatchJob { id: k, image: generate(scene, w, h) }
        })
        .collect();

    for capacity in [2usize, 8, 32] {
        let jobs_clone: Vec<BatchJob> = jobs
            .iter()
            .map(|j| BatchJob { id: j.id, image: j.image.clone() })
            .collect();
        let report = BatchServer::new(&det)
            .with_capacity(capacity)
            .run(jobs_clone, &params)?;
        println!(
            "capacity {capacity:>2}: {n} images ({w}x{h}) in {:>8.1} ms -> {:>6.2} img/s, {:>6.2} Mpix/s, {:>3} feeder stalls",
            report.wall_ns as f64 / 1e6,
            report.images_per_s(),
            report.mpix_per_s(),
            report.farm.stalls,
        );
    }
    println!("\n(backpressure: small capacity bounds memory, stalls the feeder;");
    println!(" large capacity trades memory for steady worker feed)");
    Ok(())
}
