//! Remote-sensing enhancement (Ali & Clausi [7]): CED as a feature
//! extractor on noisy captures — quantified with the paper's own
//! criteria: detection SNR (criterion 1) and localization via Pratt's
//! FOM (criterion 2), across noise levels.
//!
//! Run: `cargo run --release --example remote_sensing`

use canny_par::canny::{CannyParams, CannyPipeline};
use canny_par::image::pgm;
use canny_par::image::synth::{generate, Scene};
use canny_par::metrics;
use canny_par::scheduler::Pool;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let pool = Pool::new(4).unwrap();
    let params = CannyParams { lo: 0.06, hi: 0.18, ..CannyParams::default() };
    let (w, h) = (512, 512);

    // Ground truth: the noise-free capture's edges.
    let clean = generate(Scene::RemoteSensing { seed: 21, noise: 0.0 }, w, h);
    let truth_out = CannyPipeline::tiled(&pool).detect(&clean, &params)?;
    pgm::write_pgm(Path::new("target/figures/remote_clean.pgm"), &clean.to_u8())?;
    pgm::write_pgm(
        Path::new("target/figures/remote_truth_edges.pgm"),
        &truth_out.edges.to_image(),
    )?;

    println!("noise σ | detection SNR | Pratt FOM | precision | recall | edges");
    println!("--------+---------------+-----------+-----------+--------+------");
    for noise in [0.02f32, 0.05, 0.08, 0.12] {
        let noisy = generate(Scene::RemoteSensing { seed: 21, noise }, w, h);
        let out = CannyPipeline::tiled(&pool).detect(&noisy, &params)?;
        let snr = metrics::detection_snr(&out.nms_mag, &truth_out.edges);
        let fom = metrics::pratt_fom(&out.edges, &truth_out.edges);
        let (prec, rec) = metrics::precision_recall(&out.edges, &truth_out.edges, 1);
        println!(
            "  {noise:.2}  |     {snr:>6.2}    |   {fom:.3}   |   {prec:.3}   | {rec:.3}  | {}",
            out.edges.count_edges()
        );
        if (noise - 0.08).abs() < 1e-6 {
            pgm::write_pgm(Path::new("target/figures/remote_noisy.pgm"), &noisy.to_u8())?;
            pgm::write_pgm(
                Path::new("target/figures/remote_noisy_edges.pgm"),
                &out.edges.to_image(),
            )?;
        }
    }
    println!("\npaper [7] claim: CED (thanks to the Gaussian stage) remains a reliable");
    println!("feature extractor on remote-sensing images corrupted by point noise —");
    println!("FOM/precision degrade gracefully with σ rather than collapsing.");
    println!("images written to target/figures/remote_*.pgm");
    Ok(())
}
