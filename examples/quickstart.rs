//! Quickstart: the happy path — build a detector, run it on a scene,
//! write the input and the edge map (paper Figure 7), then replay a
//! small request stream through the serving tier (the library face of
//! `cannyd serve --synthetic 200 --lanes 2`).
//!
//! Run: `cargo run --release --example quickstart`

use canny_par::canny::{CannyParams, Engine};
use canny_par::config::RunConfig;
use canny_par::coordinator::Detector;
use canny_par::image::pgm;
use canny_par::image::synth::{generate, Scene};
use canny_par::service::{serve, ServeOptions, Trace};
use canny_par::util::timer::human_ns;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    // 1. An input image: load a PGM/PPM with `pgm::read_pgm`, or
    //    generate a synthetic scene.
    let img = generate(Scene::Shapes { seed: 7 }, 640, 480);

    // 2. A detector: pattern-parallel engine on 4 workers.
    let det = Detector::builder()
        .engine(Engine::Patterns)
        .workers(4)
        .build()?;

    // 3. Detect.
    let params = CannyParams { lo: 0.05, hi: 0.15, ..CannyParams::default() };
    let out = det.detect_full(&img, &params)?;

    println!(
        "{}x{} -> {} edge pixels ({:.2}% density) in {:.2} ms",
        img.width(),
        img.height(),
        out.edges.count_edges(),
        100.0 * out.edges.edge_density(),
        out.times.total_ns as f64 / 1e6,
    );

    // 4. Save (Figure 7: the application run).
    pgm::write_pgm(Path::new("target/figures/quickstart_input.pgm"), &img.to_u8())?;
    pgm::write_pgm(
        Path::new("target/figures/quickstart_edges.pgm"),
        &out.edges.to_image(),
    )?;
    println!("wrote target/figures/quickstart_{{input,edges}}.pgm");

    // 5. The serving tier: a deterministic synthetic client trace
    //    through admission queue -> batcher -> detector lanes. Same
    //    seed, same report — `cannyd serve` prints the full JSON.
    let cfg = RunConfig::default();
    let trace = Trace::synthetic(32, cfg.seed, cfg.arrival_rate_hz);
    let report = serve("quickstart-serve", &trace, &ServeOptions::from_config(&cfg))?;
    println!(
        "served {}/{} requests on {} lanes: p99 {} ({} batches, {} edge pixels)",
        report.completed,
        report.offered,
        report.lanes.len(),
        human_ns(report.latency.p99_ns),
        report.batches_formed,
        report.edge_pixels,
    );
    Ok(())
}
