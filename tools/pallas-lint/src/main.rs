//! `pallas-lint` binary: run every repo-invariant rule over a source
//! tree (default `rust/src`) and exit non-zero on findings.
//!
//! Exit codes: 0 = clean, 1 = findings, 2 = usage or I/O error.

use std::path::Path;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--help" || a == "-h") {
        eprintln!("usage: pallas-lint [SRC_ROOT]   (default: rust/src)");
        return ExitCode::from(2);
    }
    if args.len() > 1 {
        eprintln!("pallas-lint: expected at most one source root, got {}", args.len());
        return ExitCode::from(2);
    }
    let root = args.first().map(String::as_str).unwrap_or("rust/src");
    match pallas_lint::check_tree(Path::new(root)) {
        Ok(findings) if findings.is_empty() => {
            println!("pallas-lint: {root}: clean");
            ExitCode::SUCCESS
        }
        Ok(findings) => {
            for f in &findings {
                println!("{f}");
            }
            println!("pallas-lint: {} finding(s) in {root}", findings.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("pallas-lint: {root}: {e}");
            ExitCode::from(2)
        }
    }
}
