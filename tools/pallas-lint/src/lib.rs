//! `pallas-lint` — repo-specific invariant checks over `rust/src`.
//!
//! The checker walks Rust sources at the line/brace level (no external
//! parser dependencies): fast, dependency-free, and precise enough for
//! the five invariants this codebase otherwise keeps only by
//! convention:
//!
//! 1. **unsafe-safety** — every `unsafe` block/impl carries an adjacent
//!    `// SAFETY:` justification; `unsafe fn` declarations may carry a
//!    `# Safety` doc section instead. A comment directly above counts,
//!    reached through attribute lines and other `unsafe` lines (so an
//!    `unsafe impl Send`/`Sync` pair may share one justification).
//! 2. **clock-purity** — `Instant::now` / `SystemTime` are forbidden
//!    outside [`CLOCK_ALLOWLIST`]: every other module must take time
//!    through an injected clock so virtual-replay output stays
//!    byte-identical.
//! 3. **schema-parity** — JSON keys emitted by the report/snapshot
//!    builders (string-literal `.insert("key", …)` calls) must appear
//!    in the fenced `json` blocks of the owning module docs
//!    ([`SCHEMA_PAIRS`]), and every `REQUIRED_LINE_KEYS` entry must be
//!    both documented and emitted.
//! 4. **flag-parity** — every dashed `RunConfig::KEYS` spelling appears
//!    as `--key` in the `cannyd` HELP text, and every `--flag` in HELP
//!    is either a config key or a command-level flag (`allowed_extras`).
//! 5. **lock-order** — within [`LOCK_SCOPED_FILES`], no `.lock()` on
//!    one named mutex while a `let`-bound guard on a *different* mutex
//!    is still in scope (the deadlock-by-ordering smell).
//!
//! Test code — everything from the first `#[cfg(test)]` line to end of
//! file, which is where this repo's test modules live — is exempt from
//! rules 1, 2 and 5 and never contributes emitted keys to rule 3.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::fs;
use std::io;
use std::path::Path;

/// Files allowed to read the wall clock directly. Everything else goes
/// through the injected clocks these modules provide.
pub const CLOCK_ALLOWLIST: &[&str] = &["service/clock.rs", "util/timer.rs", "obs/snapshot.rs"];

/// Files subject to the lock-order rule (the two places where more
/// than one mutex lives in the same function's reach).
pub const LOCK_SCOPED_FILES: &[&str] = &["cache/shard.rs", "service/server.rs"];

/// (module-doc file, report/snapshot builder files) pairs: keys the
/// builders emit must be documented in the module doc's `json` blocks.
pub const SCHEMA_PAIRS: &[(&str, &[&str])] = &[
    ("obs/mod.rs", &["obs/snapshot.rs", "obs/trace.rs", "obs/merge.rs", "obs/analyze.rs"]),
    ("service/mod.rs", &["service/slo.rs", "service/calibrate.rs", "cache/stats.rs"]),
    ("stream/mod.rs", &["stream/report.rs"]),
    ("cluster/mod.rs", &["cluster/proto.rs", "cluster/report.rs"]),
];

/// One rule violation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    pub file: String,
    /// 1-based.
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

fn note(file: &SourceFile, line: usize, rule: &'static str, message: String) -> Finding {
    Finding { file: file.rel.clone(), line, rule, message }
}

/// A source file plus comment/string-stripped views. All three views
/// share newline positions, so line numbers agree everywhere.
#[derive(Clone, Debug)]
pub struct SourceFile {
    /// Path relative to the scanned root, `/`-separated.
    pub rel: String,
    pub raw: String,
    /// Comments blanked to spaces; string literals kept verbatim.
    pub code: String,
    /// Comments *and* string/char literal contents blanked.
    pub tokens: String,
    /// 0-based line index of the first `#[cfg(test)]`; `usize::MAX`
    /// when the file has no test module.
    pub test_start: usize,
}

impl SourceFile {
    pub fn new(rel: &str, text: &str) -> SourceFile {
        let (code, tokens) = scrub(text);
        let test_start =
            tokens.lines().position(|l| l.contains("#[cfg(test)]")).unwrap_or(usize::MAX);
        SourceFile { rel: rel.to_string(), raw: text.to_string(), code, tokens, test_start }
    }
}

/// Blank `c` in both views (newlines survive to keep line alignment).
fn blank(c: char, code: &mut String, tokens: &mut String) {
    let keep = if c == '\n' { '\n' } else { ' ' };
    code.push(keep);
    tokens.push(keep);
}

/// Push `c` verbatim to `code`, blanked to `tokens`.
fn literal(c: char, code: &mut String, tokens: &mut String) {
    code.push(c);
    tokens.push(if c == '\n' { '\n' } else { ' ' });
}

/// Build the `code` and `tokens` views: a character state machine over
/// line comments, nesting block comments, string/byte-string literals
/// (escape-aware), raw strings, and char-literal-vs-lifetime cases.
fn scrub(text: &str) -> (String, String) {
    let chars: Vec<char> = text.chars().collect();
    let mut code = String::with_capacity(text.len());
    let mut tokens = String::with_capacity(text.len());
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        // Line comment.
        if c == '/' && chars.get(i + 1) == Some(&'/') {
            while i < chars.len() && chars[i] != '\n' {
                blank(chars[i], &mut code, &mut tokens);
                i += 1;
            }
            continue;
        }
        // Block comment (they nest in Rust).
        if c == '/' && chars.get(i + 1) == Some(&'*') {
            let mut depth = 0usize;
            while i < chars.len() {
                if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                    depth += 1;
                    blank('/', &mut code, &mut tokens);
                    blank('*', &mut code, &mut tokens);
                    i += 2;
                } else if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    blank('*', &mut code, &mut tokens);
                    blank('/', &mut code, &mut tokens);
                    i += 2;
                    if depth == 0 {
                        break;
                    }
                } else {
                    blank(chars[i], &mut code, &mut tokens);
                    i += 1;
                }
            }
            continue;
        }
        // Raw (and raw byte) string literals: r"…", r#"…"#, br"…".
        if (c == 'r' || c == 'b') && !(i > 0 && is_ident_char(chars[i - 1])) {
            let mut r_at = i;
            if c == 'b' && chars.get(i + 1) == Some(&'r') {
                r_at = i + 1;
            }
            if chars.get(r_at) == Some(&'r') {
                let mut k = r_at + 1;
                let mut hashes = 0usize;
                while chars.get(k) == Some(&'#') {
                    hashes += 1;
                    k += 1;
                }
                if chars.get(k) == Some(&'"') {
                    while i <= k {
                        literal(chars[i], &mut code, &mut tokens);
                        i += 1;
                    }
                    while i < chars.len() {
                        let close = chars[i] == '"'
                            && (1..=hashes).all(|h| chars.get(i + h) == Some(&'#'));
                        if close {
                            for _ in 0..=hashes {
                                literal(chars[i], &mut code, &mut tokens);
                                i += 1;
                            }
                            break;
                        }
                        literal(chars[i], &mut code, &mut tokens);
                        i += 1;
                    }
                    continue;
                }
            }
        }
        // Normal string literal (incl. b"…").
        if c == '"' {
            literal('"', &mut code, &mut tokens);
            i += 1;
            while i < chars.len() {
                let d = chars[i];
                if d == '\\' && i + 1 < chars.len() {
                    literal(d, &mut code, &mut tokens);
                    literal(chars[i + 1], &mut code, &mut tokens);
                    i += 2;
                    continue;
                }
                literal(d, &mut code, &mut tokens);
                i += 1;
                if d == '"' {
                    break;
                }
            }
            continue;
        }
        // Char literal vs lifetime: `'x'` / `'\n'` are literals, `'a`
        // followed by anything but a closing quote is a lifetime.
        if c == '\'' {
            let is_char = match chars.get(i + 1) {
                Some('\\') => true,
                Some(_) => chars.get(i + 2) == Some(&'\''),
                None => false,
            };
            if is_char {
                literal('\'', &mut code, &mut tokens);
                i += 1;
                while i < chars.len() {
                    let d = chars[i];
                    if d == '\\' && i + 1 < chars.len() {
                        literal(d, &mut code, &mut tokens);
                        literal(chars[i + 1], &mut code, &mut tokens);
                        i += 2;
                        continue;
                    }
                    literal(d, &mut code, &mut tokens);
                    i += 1;
                    if d == '\'' {
                        break;
                    }
                }
                continue;
            }
        }
        code.push(c);
        tokens.push(c);
        i += 1;
    }
    (code, tokens)
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Byte offset of `word` in `line` with identifier-boundary checks on
/// both sides.
fn find_word(line: &str, word: &str) -> Option<usize> {
    let bytes = line.as_bytes();
    let mut from = 0;
    while let Some(pos) = line[from..].find(word) {
        let at = from + pos;
        let end = at + word.len();
        let before = at == 0 || !is_ident_byte(bytes[at - 1]);
        let after = end >= bytes.len() || !is_ident_byte(bytes[end]);
        if before && after {
            return Some(at);
        }
        from = end;
    }
    None
}

fn has_word(line: &str, word: &str) -> bool {
    find_word(line, word).is_some()
}

/// 1-based line number of byte offset `at`.
fn line_of(text: &str, at: usize) -> usize {
    text[..at].matches('\n').count() + 1
}

/// Byte offset where 0-based line `line` starts, if it exists.
fn byte_of_line(text: &str, line: usize) -> Option<usize> {
    if line == 0 {
        return Some(0);
    }
    if line == usize::MAX {
        return None;
    }
    let mut seen = 0;
    for (i, b) in text.bytes().enumerate() {
        if b == b'\n' {
            seen += 1;
            if seen == line {
                return Some(i + 1);
            }
        }
    }
    None
}

/// All `"…"` contents in `text` (run on the code view, where string
/// literals survive; assumes no escaped quotes in scanned literals).
fn all_quoted(text: &str) -> Vec<String> {
    let bytes = text.as_bytes();
    let mut out = Vec::new();
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == b'"' {
            if let Some(len) = text[i + 1..].find('"') {
                out.push(text[i + 1..i + 1 + len].to_string());
                i += len + 2;
                continue;
            }
        }
        i += 1;
    }
    out
}

/// The dotted receiver path immediately before byte offset `at`
/// (`shared.dispatch.lock()` at the `.lock` dot → `shared.dispatch`).
fn receiver_before(line: &str, at: usize) -> String {
    let bytes = line.as_bytes();
    let mut start = at;
    while start > 0 && (is_ident_byte(bytes[start - 1]) || bytes[start - 1] == b'.') {
        start -= 1;
    }
    line[start..at].trim_matches('.').to_string()
}

/// Rule 1: every `unsafe` site outside test code carries an adjacent
/// `// SAFETY:` justification (`unsafe fn` declarations may carry a
/// `# Safety` doc section instead).
pub fn rule_safety(file: &SourceFile) -> Vec<Finding> {
    let tok: Vec<&str> = file.tokens.lines().collect();
    let raw: Vec<&str> = file.raw.lines().collect();
    let mut out = Vec::new();
    for (i, line) in tok.iter().enumerate() {
        if i >= file.test_start {
            break;
        }
        let Some(at) = find_word(line, "unsafe") else {
            continue;
        };
        let is_fn = line[at + "unsafe".len()..].trim_start().starts_with("fn");
        if raw[i].contains("SAFETY:") || safety_above(&tok, &raw, i, is_fn) {
            continue;
        }
        let message = if is_fn {
            "`unsafe fn` without a `# Safety` doc section or `// SAFETY:` comment".to_string()
        } else {
            "`unsafe` without an adjacent `// SAFETY:` comment".to_string()
        };
        out.push(note(file, i + 1, "unsafe-safety", message));
    }
    out
}

/// Walk upward over contiguous comment / attribute / `unsafe` lines
/// looking for a safety justification for the site at `site`.
fn safety_above(tok: &[&str], raw: &[&str], site: usize, is_fn: bool) -> bool {
    let mut i = site;
    while i > 0 {
        i -= 1;
        let t = tok[i].trim();
        let r = raw[i].trim();
        let is_comment =
            t.is_empty() && (r.starts_with("//") || r.starts_with("/*") || r.starts_with('*'));
        if is_comment {
            if r.contains("SAFETY:") || (is_fn && r.contains("# Safety")) {
                return true;
            }
            continue;
        }
        if t.starts_with("#[") || has_word(t, "unsafe") {
            continue;
        }
        return false;
    }
    false
}

/// Rule 2: virtual-clock purity — direct wall-clock reads live only in
/// the allowlisted clock modules.
pub fn rule_clock(file: &SourceFile) -> Vec<Finding> {
    if CLOCK_ALLOWLIST.contains(&file.rel.as_str()) {
        return Vec::new();
    }
    let mut out = Vec::new();
    for (i, line) in file.tokens.lines().enumerate() {
        if i >= file.test_start {
            break;
        }
        for needle in ["Instant::now", "SystemTime"] {
            if has_word(line, needle) {
                let message = format!(
                    "`{needle}` outside the clock allowlist breaks virtual-replay determinism"
                );
                out.push(note(file, i + 1, "clock-purity", message));
            }
        }
    }
    out
}

/// JSON keys documented in the fenced `json` blocks of a module's
/// `//!` docs (a quoted identifier followed by `:`).
pub fn doc_json_keys(file: &SourceFile) -> BTreeSet<String> {
    let mut keys = BTreeSet::new();
    let mut in_json = false;
    for line in file.raw.lines() {
        let Some(rest) = line.trim_start().strip_prefix("//!") else {
            continue;
        };
        let body = rest.trim();
        if let Some(fence) = body.strip_prefix("```") {
            in_json = fence.starts_with("json");
            continue;
        }
        if in_json {
            collect_doc_keys(body, &mut keys);
        }
    }
    keys
}

fn collect_doc_keys(text: &str, keys: &mut BTreeSet<String>) {
    let bytes = text.as_bytes();
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == b'"' {
            if let Some(len) = text[i + 1..].find('"') {
                let key = &text[i + 1..i + 1 + len];
                let after = text[i + len + 2..].trim_start();
                if after.starts_with(':') && !key.is_empty() && key.bytes().all(is_ident_byte) {
                    keys.insert(key.to_string());
                }
                i += len + 2;
                continue;
            }
        }
        i += 1;
    }
}

/// String-literal keys passed to `.insert("…", …)` in non-test code,
/// with their 1-based lines. The key may start on the line after the
/// `insert(` (rustfmt wraps long builder lines).
pub fn emitted_keys(file: &SourceFile) -> Vec<(String, usize)> {
    let code = &file.code;
    let stop = byte_of_line(code, file.test_start).unwrap_or(code.len());
    let bytes = code.as_bytes();
    let mut out = Vec::new();
    let mut i = 0;
    while i < stop {
        if bytes[i] == b'i' && code[i..].starts_with("insert") {
            let boundary = i == 0 || !is_ident_byte(bytes[i - 1]);
            let mut j = i + "insert".len();
            if boundary && bytes.get(j) == Some(&b'(') {
                j += 1;
                while j < bytes.len() && bytes[j].is_ascii_whitespace() {
                    j += 1;
                }
                if bytes.get(j) == Some(&b'"') {
                    if let Some(len) = code[j + 1..].find('"') {
                        out.push((code[j + 1..j + 1 + len].to_string(), line_of(code, i)));
                    }
                }
            }
            i += "insert".len();
            continue;
        }
        i += 1;
    }
    out
}

/// Elements of the first `NAME … = [ "…", … ]` string-array literal at
/// or after the first occurrence of `marker` in the code view.
pub fn const_str_array(file: &SourceFile, marker: &str) -> Vec<String> {
    let code = &file.code;
    let Some(at) = code.find(marker) else {
        return Vec::new();
    };
    let tail = &code[at..];
    let Some(eq) = tail.find('=') else {
        return Vec::new();
    };
    let Some(end) = tail[eq..].find(']') else {
        return Vec::new();
    };
    all_quoted(&tail[eq..eq + end])
}

/// Rule 3: schema parity between module-doc `json` blocks and the keys
/// the report/snapshot builders actually emit, plus the explicit
/// `REQUIRED_LINE_KEYS` contract in both directions.
pub fn rule_schema(files: &BTreeMap<String, SourceFile>) -> Vec<Finding> {
    let mut out = Vec::new();
    for (doc_rel, builders) in SCHEMA_PAIRS {
        let Some(doc) = files.get(*doc_rel) else {
            continue;
        };
        let documented = doc_json_keys(doc);
        if documented.is_empty() {
            continue;
        }
        for rel in *builders {
            let Some(builder) = files.get(*rel) else {
                continue;
            };
            for (key, line) in emitted_keys(builder) {
                if !documented.contains(&key) {
                    let message = format!("emitted key `{key}` is not documented in {doc_rel}");
                    out.push(note(builder, line, "schema-parity", message));
                }
            }
        }
    }
    if let (Some(snap), Some(doc)) = (files.get("obs/snapshot.rs"), files.get("obs/mod.rs")) {
        let documented = doc_json_keys(doc);
        let emitted: BTreeSet<String> = emitted_keys(snap).into_iter().map(|(k, _)| k).collect();
        for key in const_str_array(snap, "REQUIRED_LINE_KEYS") {
            if !documented.contains(&key) {
                let message = format!("REQUIRED_LINE_KEYS `{key}` missing from obs/mod.rs docs");
                out.push(note(doc, 1, "schema-parity", message));
            }
            if !emitted.contains(&key) {
                let message = format!("REQUIRED_LINE_KEYS `{key}` is never emitted");
                out.push(note(snap, 1, "schema-parity", message));
            }
        }
    }
    out
}

/// The contents of the string literal after `marker` (escape-tolerant:
/// escaped chars are kept raw — flag scanning only needs text shape),
/// plus the marker's 1-based line.
pub fn string_const(file: &SourceFile, marker: &str) -> Option<(String, usize)> {
    let code = &file.code;
    let at = code.find(marker)?;
    let line = line_of(code, at);
    let open = at + code[at..].find('"')?;
    let bytes = code.as_bytes();
    let mut i = open + 1;
    let mut out = String::new();
    while i < bytes.len() {
        let b = bytes[i];
        if b == b'\\' && i + 1 < bytes.len() {
            out.push(bytes[i + 1] as char);
            i += 2;
            continue;
        }
        if b == b'"' {
            break;
        }
        out.push(b as char);
        i += 1;
    }
    Some((out, line))
}

/// `--flag` tokens in the HELP text (lowercase/digit/dash runs after a
/// literal `--`, trailing dashes trimmed).
pub fn help_flags(help: &str) -> BTreeSet<String> {
    let chars: Vec<char> = help.chars().collect();
    let mut out = BTreeSet::new();
    let mut i = 0;
    while i + 1 < chars.len() {
        let dash = chars[i] == '-' && chars[i + 1] == '-' && (i == 0 || chars[i - 1] != '-');
        if dash {
            let mut j = i + 2;
            while j < chars.len()
                && (chars[j].is_ascii_lowercase() || chars[j].is_ascii_digit() || chars[j] == '-')
            {
                j += 1;
            }
            let word: String = chars[i + 2..j].iter().collect();
            let flag = word.trim_matches('-');
            if !flag.is_empty() {
                out.insert(flag.to_string());
            }
            i = j + 1;
            continue;
        }
        i += 1;
    }
    out
}

/// Command-level flags from `allowed_extras` (command names from the
/// match arms come along for the ride; they are harmless here).
fn extras_set(main: &SourceFile) -> BTreeSet<String> {
    let code = &main.code;
    let Some(start) = code.find("fn allowed_extras") else {
        return BTreeSet::new();
    };
    let tail = &code[start..];
    let end = tail.find("\n}").unwrap_or(tail.len());
    all_quoted(&tail[..end]).into_iter().collect()
}

/// Rule 4: HELP-text ↔ `RunConfig::KEYS` flag parity in both
/// directions. Only dashed KEYS spellings are required in HELP (the
/// `snake_case` variants are config-file aliases).
pub fn rule_flags(files: &BTreeMap<String, SourceFile>) -> Vec<Finding> {
    let (Some(main), Some(config)) = (files.get("main.rs"), files.get("config/mod.rs")) else {
        return Vec::new();
    };
    let mut out = Vec::new();
    let keys: BTreeSet<String> = const_str_array(config, "const KEYS").into_iter().collect();
    let Some((help, help_line)) = string_const(main, "const HELP") else {
        out.push(note(main, 1, "flag-parity", "could not locate `const HELP`".to_string()));
        return out;
    };
    if keys.is_empty() {
        out.push(note(config, 1, "flag-parity", "could not locate `const KEYS`".to_string()));
        return out;
    }
    let extras = extras_set(main);
    let flags = help_flags(&help);
    for key in keys.iter().filter(|k| !k.contains('_')) {
        if !flags.contains(key) {
            let message = format!("config key `--{key}` is not documented in the cannyd HELP");
            out.push(note(config, 1, "flag-parity", message));
        }
    }
    for flag in &flags {
        if !keys.contains(flag) && !extras.contains(flag) && flag.as_str() != "help" {
            let message = format!("HELP flag `--{flag}` is not a config key or command flag");
            out.push(note(main, help_line, "flag-parity", message));
        }
    }
    out
}

/// Receivers of `.lock()` calls on this (token-view) line.
fn lock_receivers(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(pos) = line[from..].find(".lock()") {
        let at = from + pos;
        let recv = receiver_before(line, at);
        if !recv.is_empty() {
            out.push(recv);
        }
        from = at + ".lock()".len();
    }
    out
}

/// `drop(x)` / `mem::drop(x)` argument names on this line.
fn dropped_names(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(pos) = line[from..].find("drop(") {
        let at = from + pos;
        let boundary = at == 0 || !is_ident_byte(line.as_bytes()[at - 1]);
        let rest = &line[at + "drop(".len()..];
        if boundary {
            if let Some(end) = rest.find(')') {
                let name = rest[..end].trim();
                if !name.is_empty() && name.bytes().all(is_ident_byte) {
                    out.push(name.to_string());
                }
            }
        }
        from = at + "drop(".len();
    }
    out
}

/// Net `{`/`}` balance of a token-view line.
fn brace_net(line: &str) -> i64 {
    let mut net = 0;
    for c in line.chars() {
        if c == '{' {
            net += 1;
        } else if c == '}' {
            net -= 1;
        }
    }
    net
}

/// `let <pat> = <recv>.lock()…` where the lock result is actually
/// *held* (bound as a guard) rather than consumed by a trailing method
/// call on the same line. Returns (binding name, receiver).
fn lock_guard_binding(line: &str) -> Option<(String, String)> {
    let let_at = find_word(line, "let")?;
    let eq = let_at + line[let_at..].find('=')?;
    let lock_at = eq + line[eq..].find(".lock()")?;
    // What trails `.lock()` decides held vs temporary: `.unwrap()` /
    // `.expect("…")` keep the guard; any further call consumes it.
    let mut rest = &line[lock_at + ".lock()".len()..];
    loop {
        if let Some(r) = rest.strip_prefix(".unwrap()") {
            rest = r;
        } else if rest.starts_with(".expect(") {
            match rest.find(')') {
                Some(p) => rest = &rest[p + 1..],
                None => break,
            }
        } else {
            break;
        }
    }
    let rest = rest.trim_start();
    let held = rest.is_empty() || rest.starts_with(';') || rest.starts_with('{');
    if !held {
        return None;
    }
    // Binding name: the last identifier in the pattern between `let`
    // and `=` (`let mut intake` → `intake`, `let Ok(mut d)` → `d`).
    let pat = &line[let_at + "let".len()..eq];
    let mut cur = String::new();
    let mut last = String::new();
    for c in pat.chars() {
        if is_ident_char(c) {
            cur.push(c);
        } else {
            if !cur.is_empty() {
                last = cur.clone();
            }
            cur.clear();
        }
    }
    if !cur.is_empty() {
        last = cur;
    }
    if last.is_empty() || last == "mut" || last == "_" {
        return None;
    }
    let recv = receiver_before(line, lock_at);
    if recv.is_empty() {
        return None;
    }
    Some((last, recv))
}

/// Rule 5: lock-order smells — a `.lock()` on one mutex while a guard
/// on a *different* mutex is still in scope. Guards die when their
/// scope closes or when `drop(name)` appears.
pub fn rule_locks(file: &SourceFile) -> Vec<Finding> {
    if !LOCK_SCOPED_FILES.contains(&file.rel.as_str()) {
        return Vec::new();
    }
    let mut out = Vec::new();
    let mut depth: i64 = 0;
    // (binding name, receiver, scope depth at binding)
    let mut guards: Vec<(String, String, i64)> = Vec::new();
    for (i, line) in file.tokens.lines().enumerate() {
        if i >= file.test_start {
            break;
        }
        for name in dropped_names(line) {
            guards.retain(|(g, _, _)| *g != name);
        }
        for recv in lock_receivers(line) {
            for (_, held, _) in &guards {
                if *held != recv {
                    let message = format!("`.lock()` on `{recv}` while `{held}` guard is held");
                    out.push(note(file, i + 1, "lock-order", message));
                }
            }
        }
        let net = brace_net(line);
        if let Some((name, recv)) = lock_guard_binding(line) {
            guards.push((name, recv, depth + net.max(0)));
        }
        depth += net;
        guards.retain(|(_, _, d)| *d <= depth);
    }
    out
}

/// Run every rule over a set of sources keyed by root-relative path.
pub fn check_sources(files: &BTreeMap<String, SourceFile>) -> Vec<Finding> {
    let mut out = Vec::new();
    for file in files.values() {
        out.extend(rule_safety(file));
        out.extend(rule_clock(file));
        out.extend(rule_locks(file));
    }
    out.extend(rule_schema(files));
    out.extend(rule_flags(files));
    out.sort_by(|a, b| (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule)));
    out
}

/// Load every `.rs` file under `root` keyed by root-relative path.
pub fn load_tree(root: &Path) -> io::Result<BTreeMap<String, SourceFile>> {
    let mut files = BTreeMap::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        for entry in fs::read_dir(&dir)? {
            let path = entry?.path();
            if path.is_dir() {
                stack.push(path);
                continue;
            }
            if path.extension().and_then(|e| e.to_str()) != Some("rs") {
                continue;
            }
            let rel_path = path.strip_prefix(root).unwrap_or(&path);
            let rel = rel_path.to_string_lossy().replace('\\', "/");
            let text = fs::read_to_string(&path)?;
            files.insert(rel.clone(), SourceFile::new(&rel, &text));
        }
    }
    Ok(files)
}

/// Load `root` and run every rule.
pub fn check_tree(root: &Path) -> io::Result<Vec<Finding>> {
    Ok(check_sources(&load_tree(root)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one(rel: &str, text: &str) -> BTreeMap<String, SourceFile> {
        let mut m = BTreeMap::new();
        m.insert(rel.to_string(), SourceFile::new(rel, text));
        m
    }

    fn rules_of(findings: &[Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn safety_flags_bare_unsafe_block() {
        let src = "fn f() -> u32 {\n    unsafe { danger() }\n}\n";
        let found = rule_safety(&SourceFile::new("x.rs", src));
        assert_eq!(rules_of(&found), ["unsafe-safety"]);
        assert_eq!(found[0].line, 2);
    }

    #[test]
    fn safety_accepts_adjacent_comment_and_test_code() {
        let src = "fn f() -> u32 {\n    // SAFETY: f is only called single-threaded.\n    \
                   unsafe { danger() }\n}\n#[cfg(test)]\nmod tests {\n    fn g() -> u32 {\n        \
                   unsafe { danger() }\n    }\n}\n";
        assert!(rule_safety(&SourceFile::new("x.rs", src)).is_empty());
    }

    #[test]
    fn safety_shared_comment_covers_impl_pair_and_doc_covers_fn() {
        let src = "// SAFETY: disjoint ranges only, per the module contract.\n\
                   unsafe impl<T: Send> Send for S<T> {}\n\
                   unsafe impl<T: Send> Sync for S<T> {}\n\
                   /// Doc.\n///\n/// # Safety\n/// Caller keeps `i` exclusive.\n\
                   #[allow(clippy::mut_from_ref)]\n\
                   pub unsafe fn write(&self, i: usize) {}\n";
        assert!(rule_safety(&SourceFile::new("x.rs", src)).is_empty());
    }

    #[test]
    fn safety_ignores_commented_and_quoted_unsafe() {
        let src = "fn f() {\n    // unsafe is discussed here only\n    \
                   let s = \"unsafe { }\";\n}\n";
        assert!(rule_safety(&SourceFile::new("x.rs", src)).is_empty());
    }

    #[test]
    fn clock_flags_instant_now_outside_allowlist() {
        let src = "fn f() {\n    let t = Instant::now();\n}\n";
        let found = rule_clock(&SourceFile::new("canny/pipeline.rs", src));
        assert_eq!(rules_of(&found), ["clock-purity"]);
        assert_eq!(found[0].line, 2);
    }

    #[test]
    fn clock_allows_allowlisted_files_and_test_code() {
        let src = "fn f() {\n    let t = Instant::now();\n}\n";
        assert!(rule_clock(&SourceFile::new("util/timer.rs", src)).is_empty());
        let test_only = "fn f() {}\n#[cfg(test)]\nmod tests {\n    \
                         fn g() { let t = Instant::now(); }\n}\n";
        assert!(rule_clock(&SourceFile::new("canny/pipeline.rs", test_only)).is_empty());
    }

    const OBS_DOC: &str = "//! Telemetry.\n//!\n//! ```json\n//! {\"seq\": 0, \"tier\": \
                           \"serve\"}\n//! ```\npub struct T;\n";

    #[test]
    fn schema_flags_undocumented_emitted_key() {
        let mut files = one("obs/mod.rs", OBS_DOC);
        let snap = "fn build(m: &mut M) {\n    m.insert(\"seq\".into(), 1);\n    \
                    m.insert(\"stray\".into(), 2);\n}\n";
        files.insert("obs/snapshot.rs".into(), SourceFile::new("obs/snapshot.rs", snap));
        let found = rule_schema(&files);
        assert_eq!(rules_of(&found), ["schema-parity"]);
        assert!(found[0].message.contains("`stray`"));
        assert_eq!(found[0].line, 3);
    }

    #[test]
    fn schema_accepts_documented_keys_and_checks_required_list() {
        let mut files = one("obs/mod.rs", OBS_DOC);
        let snap = "pub const REQUIRED_LINE_KEYS: [&str; 2] = [\"seq\", \"tier\"];\n\
                    fn build(m: &mut M) {\n    m.insert(\"seq\".into(), 1);\n    \
                    m.insert(\"tier\".into(), 2);\n}\n";
        files.insert("obs/snapshot.rs".into(), SourceFile::new("obs/snapshot.rs", snap));
        assert!(rule_schema(&files).is_empty());
    }

    #[test]
    fn schema_flags_required_key_never_emitted_or_documented() {
        let mut files = one("obs/mod.rs", OBS_DOC);
        let snap = "pub const REQUIRED_LINE_KEYS: [&str; 2] = [\"seq\", \"ghost\"];\n\
                    fn build(m: &mut M) {\n    m.insert(\"seq\".into(), 1);\n}\n";
        files.insert("obs/snapshot.rs".into(), SourceFile::new("obs/snapshot.rs", snap));
        let found = rule_schema(&files);
        assert_eq!(found.len(), 2, "{found:?}");
        assert!(found.iter().all(|f| f.message.contains("`ghost`")));
    }

    #[test]
    fn schema_reads_multiline_inserts_and_skips_tests() {
        let mut files = one("obs/mod.rs", OBS_DOC);
        let snap = "fn build(m: &mut M) {\n    m.insert(\n        \"seq\".into(),\n        \
                    1,\n    );\n}\n#[cfg(test)]\nmod tests {\n    fn t(m: &mut M) { \
                    m.insert(\"not_a_schema_key\".into(), 3); }\n}\n";
        files.insert("obs/snapshot.rs".into(), SourceFile::new("obs/snapshot.rs", snap));
        assert!(rule_schema(&files).is_empty());
    }

    const CONFIG_SRC: &str = "impl RunConfig {\n    pub const KEYS: &'static [&'static str] = \
                              &[\"alpha\", \"beta\", \"beta_us\"];\n}\n";

    fn main_src(help_flags_line: &str) -> String {
        format!(
            "const HELP: &str = \"\\\nUSAGE: cannyd run\n{help_flags_line}\n\";\n\
             fn allowed_extras(cmd: &str) -> &'static [&'static str] {{\n    match cmd {{\n        \
             \"run\" => &[\"config\", \"input\"],\n        _ => &[\"config\"],\n    }}\n}}\n"
        )
    }

    #[test]
    fn flags_accepts_matching_help_and_keys() {
        let mut files = one("main.rs", &main_src("--alpha N --beta F --input X --config FILE"));
        files.insert("config/mod.rs".into(), SourceFile::new("config/mod.rs", CONFIG_SRC));
        let found = rule_flags(&files);
        assert!(found.is_empty(), "{found:?}");
    }

    #[test]
    fn flags_catches_orphan_help_flag_and_missing_key() {
        let mut files = one("main.rs", &main_src("--alpha N --gamma Q"));
        files.insert("config/mod.rs".into(), SourceFile::new("config/mod.rs", CONFIG_SRC));
        let found = rule_flags(&files);
        assert_eq!(rules_of(&found), ["flag-parity", "flag-parity"]);
        let all = format!("{found:?}");
        assert!(all.contains("`--beta`"), "{all}");
        assert!(all.contains("`--gamma`"), "{all}");
    }

    #[test]
    fn locks_flags_nested_distinct_mutexes() {
        let src = "fn f(a: &S, b: &S) {\n    let g = a.inner.lock().unwrap();\n    \
                   let h = b.other.lock().unwrap();\n}\n";
        let found = rule_locks(&SourceFile::new("cache/shard.rs", src));
        assert_eq!(rules_of(&found), ["lock-order"]);
        assert_eq!(found[0].line, 3);
    }

    #[test]
    fn locks_allows_sequential_scopes_temporaries_and_other_files() {
        let seq = "fn f(a: &S, b: &S) {\n    {\n        let g = a.inner.lock().unwrap();\n        \
                   g.touch();\n    }\n    {\n        let h = b.other.lock().unwrap();\n        \
                   h.touch();\n    }\n}\n";
        assert!(rule_locks(&SourceFile::new("cache/shard.rs", seq)).is_empty());
        let tmp = "fn f(a: &S, b: &S) {\n    let missed = a.inner.lock().unwrap().missed();\n    \
                   let h = b.other.lock().unwrap();\n}\n";
        assert!(rule_locks(&SourceFile::new("service/server.rs", tmp)).is_empty());
        let nested = "fn f(a: &S, b: &S) {\n    let g = a.inner.lock().unwrap();\n    \
                      let h = b.other.lock().unwrap();\n}\n";
        assert!(rule_locks(&SourceFile::new("stream/mod.rs", nested)).is_empty());
    }

    #[test]
    fn locks_respects_explicit_drop() {
        let src = "fn f(a: &S, b: &S) {\n    let g = a.inner.lock().unwrap();\n    \
                   drop(g);\n    let h = b.other.lock().unwrap();\n}\n";
        assert!(rule_locks(&SourceFile::new("cache/shard.rs", src)).is_empty());
    }

    #[test]
    fn check_sources_orders_findings_by_file_and_line() {
        let mut files = one("b.rs", "fn f() { unsafe { x() } }\n");
        files.insert("a.rs".into(), SourceFile::new("a.rs", "fn g() { unsafe { y() } }\n"));
        let found = check_sources(&files);
        assert_eq!(found.len(), 2);
        assert_eq!(found[0].file, "a.rs");
        assert_eq!(found[1].file, "b.rs");
    }
}
