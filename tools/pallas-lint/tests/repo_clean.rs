//! The lint must pass on the repo it ships in: zero findings over
//! `rust/src`, through both the library entry point and the binary.

use std::path::PathBuf;
use std::process::Command;

fn src_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../../rust/src")
}

#[test]
fn repo_sources_have_zero_findings() {
    let findings = pallas_lint::check_tree(&src_root()).expect("walk rust/src");
    let rendered: Vec<String> = findings.iter().map(|f| f.to_string()).collect();
    assert!(findings.is_empty(), "lint findings:\n{}", rendered.join("\n"));
}

#[test]
fn repo_rules_actually_ran() {
    // Guard against the silent-pass failure mode: if the tree moved or
    // the markers rot, the rules would "pass" by scanning nothing.
    let files = pallas_lint::load_tree(&src_root()).expect("walk rust/src");
    assert!(files.len() > 30, "expected a real tree, got {} files", files.len());
    for rel in ["main.rs", "config/mod.rs", "obs/mod.rs", "obs/snapshot.rs"] {
        assert!(files.contains_key(rel), "missing {rel}");
    }
    let snap = &files["obs/snapshot.rs"];
    assert!(!pallas_lint::const_str_array(snap, "REQUIRED_LINE_KEYS").is_empty());
    let main = &files["main.rs"];
    let (help, _) = pallas_lint::string_const(main, "const HELP").expect("HELP const");
    assert!(pallas_lint::help_flags(&help).len() > 20, "HELP flag extraction rotted");
}

#[test]
fn binary_exits_zero_on_repo() {
    let out = Command::new(env!("CARGO_BIN_EXE_pallas-lint"))
        .arg(src_root())
        .output()
        .expect("run pallas-lint binary");
    assert!(
        out.status.success(),
        "stdout:\n{}\nstderr:\n{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
}
